//! Serial-link far memory model (CXL-like), per the paper's Figure 7:
//! size-dependent packet delay, per-direction bandwidth limits, a
//! configurable *additional* latency, and a remote memory controller
//! modeled with the same DDR4-lite bank model as local DRAM. Coherence
//! internals are intentionally not modeled (paper §6.1).
//!
//! The per-direction timing arithmetic (serialization, exact RTT split,
//! zero-mean jitter) lives in [`LinkFront`], shared by [`FarLink`] and the
//! pooled/distribution backends in [`crate::mem::backend`] — one
//! implementation, so the backends can never drift apart bit-by-bit.

use super::dram::Dram;
use crate::config::FarMemConfig;
use crate::util::prng::Xoshiro256;

/// Shared per-direction link front end: request/response serialization
/// state, the exact request/response split of the configured added
/// latency, and the zero-mean jitter amplitude. Every far-memory data
/// plane that models a serial link composes this one struct, so the
/// RTT-split and jitter arithmetic exists in exactly one place.
pub struct LinkFront {
    req_free_at: u64,
    resp_free_at: u64,
    /// Cycles per byte on each direction.
    cycles_per_byte: f64,
    /// Request/response-direction propagation. The two sum to the
    /// configured added latency *exactly* (odd cycle counts put the spare
    /// cycle on the response direction), so `min_round_trip()` never
    /// under-reports the configuration.
    req_way_cycles: u64,
    resp_way_cycles: u64,
    jitter_cycles: u64,
    header_bytes: usize,
}

impl LinkFront {
    pub fn new(cfg: &FarMemConfig, freq_ghz: f64) -> Self {
        let added_cycles = crate::util::ns_to_cycles(cfg.added_latency_ns, freq_ghz);
        Self {
            req_free_at: 0,
            resp_free_at: 0,
            cycles_per_byte: freq_ghz / cfg.bandwidth_gbps,
            req_way_cycles: added_cycles / 2,
            resp_way_cycles: added_cycles - added_cycles / 2,
            jitter_cycles: (added_cycles as f64 * cfg.jitter_frac) as u64,
            header_bytes: cfg.header_bytes,
        }
    }

    /// Serialization delay of a `bytes`-byte packet on one direction.
    #[inline]
    pub fn ser(&self, bytes: usize) -> u64 {
        ((bytes as f64) * self.cycles_per_byte).ceil() as u64
    }

    /// Serialize a request packet (header + `payload` bytes); returns when
    /// it departs the requester.
    pub fn depart_request(&mut self, cycle: u64, payload: usize) -> u64 {
        let depart = cycle.max(self.req_free_at) + self.ser(self.header_bytes + payload);
        self.req_free_at = depart;
        depart
    }

    /// Serialize a response packet (header + `payload` bytes) once the
    /// remote side finished at `remote_done`; returns when it departs the
    /// remote end.
    pub fn depart_response(&mut self, remote_done: u64, payload: usize) -> u64 {
        let depart =
            remote_done.max(self.resp_free_at) + self.ser(self.header_bytes + payload);
        self.resp_free_at = depart;
        depart
    }

    /// Zero-mean jitter in `[-jitter_cycles, +jitter_cycles]`, drawn from
    /// the caller's PRNG stream. The old implementation sampled
    /// `below(2*jitter)` and *added* it, silently raising the mean latency
    /// by `jitter_frac * added_latency`; sampling symmetrically keeps the
    /// empirical mean at the configured RTT.
    #[inline]
    pub fn jitter(&self, rng: &mut Xoshiro256) -> i64 {
        if self.jitter_cycles == 0 {
            0
        } else {
            rng.below(2 * self.jitter_cycles + 1) as i64 - self.jitter_cycles as i64
        }
    }

    /// Request-direction propagation cycles.
    #[inline]
    pub fn req_way_cycles(&self) -> u64 {
        self.req_way_cycles
    }

    /// Response-direction propagation cycles.
    #[inline]
    pub fn resp_way_cycles(&self) -> u64 {
        self.resp_way_cycles
    }

    /// The configured added round-trip latency, exactly (both directions).
    #[inline]
    pub fn min_round_trip(&self) -> u64 {
        self.req_way_cycles + self.resp_way_cycles
    }
}

pub struct FarLink {
    front: LinkFront,
    remote: Dram,
    rng: Xoshiro256,
    pub inflight: u64,
    pub reads: u64,
    pub writes: u64,
    pub bytes: u64,
}

/// A completed far access returns at `done`; `req_accepted` tells the
/// caller when the request direction freed up (back-pressure modeling).
#[derive(Debug, Clone, Copy)]
pub struct FarTiming {
    pub done: u64,
}

impl FarLink {
    pub fn new(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Self {
        Self {
            front: LinkFront::new(cfg, freq_ghz),
            remote: Dram::new(&cfg.remote_dram, freq_ghz),
            rng: Xoshiro256::new(seed ^ 0xFA12_31AB),
            inflight: 0,
            reads: 0,
            writes: 0,
            bytes: 0,
        }
    }

    /// Issue a read of `bytes` payload starting at `cycle`; returns the
    /// absolute cycle the response data arrives back at the requester.
    /// Caller must later call [`FarLink::complete`].
    pub fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.reads += 1;
        self.bytes += bytes as u64;
        self.inflight += 1;
        // Request packet: header only.
        let req_depart = self.front.depart_request(cycle, 0);
        let jitter = self.front.jitter(&mut self.rng);
        let arrive_remote =
            add_signed(req_depart + self.front.req_way_cycles(), jitter).max(req_depart);
        // Remote MC services (possibly multiple lines).
        let mut remote_done = arrive_remote;
        let lines = bytes.div_ceil(64).max(1);
        for l in 0..lines {
            remote_done = remote_done.max(self.remote.service(
                arrive_remote,
                addr + (l * 64) as u64,
                false,
            ));
        }
        // Response packet: header + payload, serialized on response dir.
        let resp_depart = self.front.depart_response(remote_done, bytes);
        FarTiming { done: resp_depart + self.front.resp_way_cycles() }
    }

    /// Issue a write of `bytes` payload; returns the cycle the write ack
    /// arrives back (the paper's astore completion notification).
    pub fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.writes += 1;
        self.bytes += bytes as u64;
        self.inflight += 1;
        // Request packet carries the payload.
        let req_depart = self.front.depart_request(cycle, bytes);
        let jitter = self.front.jitter(&mut self.rng);
        let arrive_remote =
            add_signed(req_depart + self.front.req_way_cycles(), jitter).max(req_depart);
        let mut remote_done = arrive_remote;
        let lines = bytes.div_ceil(64).max(1);
        for l in 0..lines {
            remote_done = remote_done.max(self.remote.service(
                arrive_remote,
                addr + (l * 64) as u64,
                true,
            ));
        }
        // Ack: header-sized response.
        let resp_depart = self.front.depart_response(remote_done, 0);
        FarTiming { done: resp_depart + self.front.resp_way_cycles() }
    }

    /// Posted write (dirty-line writeback): consumes request-direction
    /// bandwidth and remote service, no ack tracked.
    pub fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        self.writes += 1;
        self.bytes += bytes as u64;
        let req_depart = self.front.depart_request(cycle, bytes);
        let arrive = req_depart + self.front.req_way_cycles();
        self.remote.service(arrive, addr, true);
    }

    /// Mark one tracked request complete (MLP accounting).
    pub fn complete(&mut self) {
        debug_assert!(self.inflight > 0);
        self.inflight -= 1;
    }

    /// The configured added round-trip latency, exactly (both directions).
    pub fn min_round_trip(&self) -> u64 {
        self.front.min_round_trip()
    }
}

/// `base + delta` with a signed delta, saturating at zero.
#[inline]
pub(crate) fn add_signed(base: u64, delta: i64) -> u64 {
    if delta >= 0 {
        base + delta as u64
    } else {
        base.saturating_sub(delta.unsigned_abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FarMemConfig;

    fn link(added_ns: f64) -> FarLink {
        let mut cfg = FarMemConfig::default();
        cfg.added_latency_ns = added_ns;
        cfg.jitter_frac = 0.0;
        FarLink::new(&cfg, 3.0, 1)
    }

    #[test]
    fn read_latency_includes_added_latency() {
        let mut l = link(1000.0); // 3000 cycles round trip
        let t = l.read(0, 0x0, 64);
        assert!(t.done >= 3000, "done={} must include 3000-cycle RTT", t.done);
        assert!(t.done < 3000 + 500, "done={} has too much overhead", t.done);
    }

    #[test]
    fn latency_scales_with_config() {
        let mut a = link(100.0);
        let mut b = link(5000.0);
        let ta = a.read(0, 0, 64).done;
        let tb = b.read(0, 0, 64).done;
        assert!(tb > ta + 14_000, "5us vs 0.1us must differ by ~14.7k cycles");
    }

    #[test]
    fn bandwidth_serializes_parallel_reads() {
        let mut l = link(1000.0);
        // Issue 100 64B reads at cycle 0: response direction must serialize
        // 100 * 80B at 16 GB/s @3GHz = 15 cycles each.
        let mut last = 0;
        for i in 0..100 {
            last = l.read(0, i * 4096, 64).done;
        }
        assert!(last >= 3000 + 90 * 15, "bandwidth cap not enforced: {last}");
        assert_eq!(l.inflight, 100);
        for _ in 0..100 {
            l.complete();
        }
        assert_eq!(l.inflight, 0);
    }

    #[test]
    fn small_payloads_serialize_faster() {
        let mut big = link(1000.0);
        let mut small = link(1000.0);
        let mut t_big = 0;
        let mut t_small = 0;
        for i in 0..200 {
            t_big = big.read(0, i * 4096, 64).done;
            t_small = small.read(0, i * 4096, 8).done;
        }
        assert!(
            t_small < t_big,
            "8B payloads ({t_small}) must stream faster than 64B ({t_big})"
        );
    }

    #[test]
    fn write_ack_round_trip() {
        let mut l = link(1000.0);
        let t = l.write(0, 0, 8);
        assert!(t.done >= 3000);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let mk = || {
            let mut cfg = FarMemConfig::default();
            cfg.added_latency_ns = 1000.0;
            cfg.jitter_frac = 0.05;
            FarLink::new(&cfg, 3.0, 7)
        };
        let mut a = mk();
        let mut b = mk();
        for i in 0..50 {
            let ta = a.read(i * 100, i * 64, 64).done;
            let tb = b.read(i * 100, i * 64, 64).done;
            assert_eq!(ta, tb, "same seed must give same jitter");
        }
    }

    #[test]
    fn odd_rtt_split_sums_exactly() {
        // 333 ns @3GHz = 999 cycles: the old `added/2` split dropped a
        // cycle, so min_round_trip() under-reported the configuration.
        let mut cfg = FarMemConfig::default();
        cfg.added_latency_ns = 333.0;
        cfg.jitter_frac = 0.0;
        let l = FarLink::new(&cfg, 3.0, 1);
        assert_eq!(l.min_round_trip(), 999);
        let even = link(1000.0);
        assert_eq!(even.min_round_trip(), 3000);
    }

    #[test]
    fn link_front_split_is_exact_and_jitterless_when_disabled() {
        // The shared front end (now also composed by FarLink) preserves the
        // exact RTT split and produces zero jitter when disabled.
        let mut cfg = FarMemConfig::default();
        cfg.added_latency_ns = 777.0; // 2331 cycles, odd split
        cfg.jitter_frac = 0.0;
        let front = LinkFront::new(&cfg, 3.0);
        assert_eq!(front.req_way_cycles() + front.resp_way_cycles(), 2331);
        assert_eq!(front.min_round_trip(), 2331);
        let mut rng = Xoshiro256::new(9);
        for _ in 0..16 {
            assert_eq!(front.jitter(&mut rng), 0);
        }
    }

    #[test]
    fn jitter_is_zero_mean() {
        // The empirical mean latency with jitter enabled must match the
        // jitter-free mean: identical access patterns, spaced far enough
        // apart that serialization and the remote MC behave identically.
        let mk = |frac: f64| {
            let mut cfg = FarMemConfig::default();
            cfg.added_latency_ns = 1000.0; // 3000-cycle RTT
            cfg.jitter_frac = frac;
            FarLink::new(&cfg, 3.0, 99)
        };
        let mut with_jitter = mk(0.10);
        let mut without = mk(0.0);
        let n = 3000u64;
        let mut sum_j = 0u64;
        let mut sum_0 = 0u64;
        for i in 0..n {
            let cycle = i * 20_000;
            let addr = i * 4096;
            sum_j += with_jitter.read(cycle, addr, 64).done - cycle;
            sum_0 += without.read(cycle, addr, 64).done - cycle;
        }
        let mean_j = sum_j as f64 / n as f64;
        let mean_0 = sum_0 as f64 / n as f64;
        // Uniform jitter in [-300, +300]: the standard error of the mean
        // over 3000 samples is ~3.2 cycles; 30 cycles (1% of RTT) is a
        // >9-sigma bound, so a reintroduced bias (+300 mean shift) fails
        // loudly while honest sampling noise never does.
        assert!(
            (mean_j - mean_0).abs() < 30.0,
            "jitter must be zero-mean: with={mean_j:.1} without={mean_0:.1}"
        );
        // And the jitter-free mean itself contains the exact configured RTT.
        assert!(mean_0 >= 3000.0, "mean {mean_0} must include the full RTT");
    }

    #[test]
    fn large_block_read_spans_lines() {
        let mut l = link(1000.0);
        let t64 = link(1000.0).read(0, 0, 64).done;
        let t512 = l.read(0, 0, 512).done;
        // 512B: more serialization + more remote lines.
        assert!(t512 > t64);
        // But far less than 8 independent reads end-to-end.
        assert!(t512 < t64 + 8 * 3000);
    }
}
