//! Pluggable far-memory data planes behind the [`FarBackend`] trait.
//!
//! The paper evaluates one scenario — a CXL-like serial link — but its
//! premise (far latency is long *and highly variable*) covers a family of
//! data planes. Each backend here is one such scenario, selectable per run
//! via `FarMemConfig::backend` and sweepable as a grid axis:
//!
//! * `serial-link` — [`FarLink`], the paper's Figure 7 model, unchanged
//!   and the default.
//! * `pooled` — a multi-channel disaggregated memory pool: every channel
//!   owns an independent remote memory controller and a bounded service
//!   queue; a full queue back-pressures new arrivals onto the oldest
//!   outstanding request (congestion, not just bandwidth, bounds tail
//!   latency).
//! * `distribution` — propagation latency sampled per request from a
//!   lognormal or bimodal distribution whose *mean* is the configured
//!   added latency, so sweeps compare equal-mean scenarios that differ
//!   only in variability (zero-mean by construction, like the serial
//!   link's fixed-amplitude jitter).
//! * `hybrid` — a fast-path/slow-path split: a configured fraction of
//!   accesses hit a near tier at `near_latency_ns` while the rest traverse
//!   the full serial link (RDMA/swap hybrid data planes).
//!
//! All randomness is drawn from per-instance [`Xoshiro256`] streams seeded
//! from the run seed, so every backend is bit-for-bit deterministic and
//! sweep CSVs stay byte-identical across `--jobs` counts.

use super::dram::Dram;
use super::link::{add_signed, FarLink, FarTiming};
use crate::config::{FarBackendKind, FarMemConfig, LatencyDist};
use crate::util::prng::Xoshiro256;
use std::collections::VecDeque;

/// One far-memory data plane: issues reads/writes with absolute-cycle
/// completion times and tracks in-flight requests for MLP accounting.
pub trait FarBackend: Send {
    /// Which scenario this backend models (CSV/report tagging).
    fn kind(&self) -> FarBackendKind;

    /// Issue a read of `bytes` payload starting at `cycle`; returns the
    /// absolute cycle the response data arrives back at the requester.
    /// Caller must later call [`FarBackend::complete`].
    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming;

    /// Issue a write; returns the cycle the ack arrives back.
    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming;

    /// Posted write (dirty-line writeback): no ack tracked.
    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize);

    /// Mark one tracked request complete (MLP accounting).
    fn complete(&mut self);

    /// Requests currently in flight (the Fig 9 metric).
    fn inflight(&self) -> u64;

    /// The *mean* added round-trip latency in cycles.
    fn min_round_trip(&self) -> u64;
}

/// Construct the backend selected by `cfg.backend`.
pub fn build(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Box<dyn FarBackend> {
    match cfg.backend {
        FarBackendKind::SerialLink => Box::new(FarLink::new(cfg, freq_ghz, seed)),
        FarBackendKind::Pooled => Box::new(PooledBackend::new(cfg, freq_ghz, seed)),
        FarBackendKind::Distribution => Box::new(DistributionBackend::new(cfg, freq_ghz, seed)),
        FarBackendKind::Hybrid => Box::new(HybridBackend::new(cfg, freq_ghz, seed)),
    }
}

impl FarBackend for FarLink {
    fn kind(&self) -> FarBackendKind {
        FarBackendKind::SerialLink
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        FarLink::read(self, cycle, addr, bytes)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        FarLink::write(self, cycle, addr, bytes)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        FarLink::posted_write(self, cycle, addr, bytes)
    }

    fn complete(&mut self) {
        FarLink::complete(self)
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn min_round_trip(&self) -> u64 {
        FarLink::min_round_trip(self)
    }
}

/// Shared per-direction link front end (serialization + propagation), used
/// by the pooled and distribution backends so they differ from the serial
/// link only in the part they model differently.
struct LinkFront {
    req_free_at: u64,
    resp_free_at: u64,
    cycles_per_byte: f64,
    req_way_cycles: u64,
    resp_way_cycles: u64,
    header_bytes: usize,
}

impl LinkFront {
    fn new(cfg: &FarMemConfig, freq_ghz: f64) -> Self {
        let added_cycles = crate::util::ns_to_cycles(cfg.added_latency_ns, freq_ghz);
        Self {
            req_free_at: 0,
            resp_free_at: 0,
            cycles_per_byte: freq_ghz / cfg.bandwidth_gbps,
            req_way_cycles: added_cycles / 2,
            resp_way_cycles: added_cycles - added_cycles / 2,
            header_bytes: cfg.header_bytes,
        }
    }

    #[inline]
    fn ser(&self, bytes: usize) -> u64 {
        ((bytes as f64) * self.cycles_per_byte).ceil() as u64
    }

    /// Serialize a request packet of `payload` bytes; returns when it
    /// departs the requester.
    fn depart_request(&mut self, cycle: u64, payload: usize) -> u64 {
        let depart = cycle.max(self.req_free_at) + self.ser(self.header_bytes + payload);
        self.req_free_at = depart;
        depart
    }

    /// Serialize a response packet of `payload` bytes once the remote side
    /// finished at `remote_done`; returns when it departs the remote end.
    fn depart_response(&mut self, remote_done: u64, payload: usize) -> u64 {
        let depart =
            remote_done.max(self.resp_free_at) + self.ser(self.header_bytes + payload);
        self.resp_free_at = depart;
        depart
    }
}

// (Per-request read/write/byte counters live in the global `Stats`; the
// backends only track in-flight counts for MLP accounting.)

// ------------------------------------------------------------------ pooled

/// One channel of the disaggregated pool: an independent remote memory
/// controller plus a bounded outstanding-request queue.
struct Channel {
    remote: Dram,
    /// Completion cycles of requests this channel is still servicing, in
    /// issue order (service starts are monotone, so this stays sorted
    /// closely enough for drain-the-front bookkeeping).
    busy: VecDeque<u64>,
    depth: usize,
    congested: u64,
}

impl Channel {
    /// Service `lines` cache lines arriving at `at`. When the channel's
    /// queue is full the request waits for the oldest outstanding one to
    /// drain first — congestion back-pressure, the pool's signature
    /// behaviour.
    fn service(&mut self, at: u64, addr: u64, lines: usize, is_write: bool) -> u64 {
        while self.busy.front().is_some_and(|&d| d <= at) {
            self.busy.pop_front();
        }
        let start = if self.busy.len() >= self.depth {
            self.congested += 1;
            let head = self.busy.pop_front().unwrap_or(at);
            head.max(at)
        } else {
            at
        };
        let mut done = start;
        for l in 0..lines {
            done = done.max(self.remote.service(start, addr + (l * 64) as u64, is_write));
        }
        self.busy.push_back(done);
        done
    }
}

/// Multi-channel disaggregated memory pool behind a serial link front end
/// (including the link's zero-mean propagation jitter, so the pool differs
/// from `serial-link` only in its remote side).
pub struct PooledBackend {
    front: LinkFront,
    channels: Vec<Channel>,
    jitter_cycles: u64,
    rng: Xoshiro256,
    inflight: u64,
}

impl PooledBackend {
    pub fn new(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Self {
        let n = cfg.pool_channels.max(1);
        let added_cycles = crate::util::ns_to_cycles(cfg.added_latency_ns, freq_ghz);
        Self {
            front: LinkFront::new(cfg, freq_ghz),
            channels: (0..n)
                .map(|_| Channel {
                    remote: Dram::new(&cfg.remote_dram, freq_ghz),
                    busy: VecDeque::new(),
                    depth: cfg.pool_queue_depth.max(1),
                    congested: 0,
                })
                .collect(),
            jitter_cycles: (added_cycles as f64 * cfg.jitter_frac) as u64,
            rng: Xoshiro256::new(seed ^ 0x900_1ED),
            inflight: 0,
        }
    }

    /// Zero-mean jitter, same scheme as [`FarLink`].
    #[inline]
    fn jitter(&mut self) -> i64 {
        if self.jitter_cycles == 0 {
            0
        } else {
            self.rng.below(2 * self.jitter_cycles + 1) as i64 - self.jitter_cycles as i64
        }
    }

    /// Requests delayed by a full channel queue (observability/tests).
    pub fn congestion_events(&self) -> u64 {
        self.channels.iter().map(|c| c.congested).sum()
    }

    #[inline]
    fn channel_of(&self, addr: u64) -> usize {
        // Multiplicative hash so strided access patterns spread across
        // channels instead of aliasing onto one.
        (((addr >> 6).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize)
            % self.channels.len()
    }

    fn access(&mut self, cycle: u64, addr: u64, bytes: usize, is_write: bool) -> FarTiming {
        self.inflight += 1;
        let req_payload = if is_write { bytes } else { 0 };
        let depart = self.front.depart_request(cycle, req_payload);
        let jitter = self.jitter();
        let arrive = add_signed(depart + self.front.req_way_cycles, jitter).max(depart);
        let lines = bytes.div_ceil(64).max(1);
        let ch = self.channel_of(addr);
        let remote_done = self.channels[ch].service(arrive, addr, lines, is_write);
        let resp_payload = if is_write { 0 } else { bytes };
        let resp_depart = self.front.depart_response(remote_done, resp_payload);
        FarTiming { done: resp_depart + self.front.resp_way_cycles }
    }
}

impl FarBackend for PooledBackend {
    fn kind(&self) -> FarBackendKind {
        FarBackendKind::Pooled
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, false)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, true)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        let depart = self.front.depart_request(cycle, bytes);
        let arrive = depart + self.front.req_way_cycles;
        let ch = self.channel_of(addr);
        self.channels[ch].service(arrive, addr, bytes.div_ceil(64).max(1), true);
    }

    fn complete(&mut self) {
        debug_assert!(self.inflight > 0);
        self.inflight -= 1;
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn min_round_trip(&self) -> u64 {
        self.front.req_way_cycles + self.front.resp_way_cycles
    }
}

// ------------------------------------------------------------ distribution

/// Per-request propagation latency sampled from a configured distribution
/// with mean equal to the configured added latency. `jitter_frac` is
/// deliberately ignored here: the sampled distribution *is* the
/// variability model, and layering uniform jitter on top would skew the
/// configured shape.
pub struct DistributionBackend {
    front: LinkFront,
    remote: Dram,
    rng: Xoshiro256,
    mean_cycles: u64,
    dist: LatencyDist,
    sigma: f64,
    tail_frac: f64,
    tail_mult: f64,
    inflight: u64,
}

impl DistributionBackend {
    pub fn new(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Self {
        Self {
            front: LinkFront::new(cfg, freq_ghz),
            remote: Dram::new(&cfg.remote_dram, freq_ghz),
            rng: Xoshiro256::new(seed ^ 0xD157_0B4C),
            mean_cycles: crate::util::ns_to_cycles(cfg.added_latency_ns, freq_ghz),
            dist: cfg.dist,
            sigma: cfg.dist_sigma,
            tail_frac: cfg.dist_tail_frac,
            tail_mult: cfg.dist_tail_mult,
            inflight: 0,
        }
    }

    /// Sample one round-trip propagation latency. Both families keep the
    /// mean at `mean_cycles` exactly (zero-mean variability), so sweeps
    /// compare equal-mean scenarios that differ only in shape.
    fn sample_rtt(&mut self) -> u64 {
        let mean = self.mean_cycles.max(1) as f64;
        let sample = match self.dist {
            LatencyDist::Lognormal => {
                if self.sigma == 0.0 {
                    mean
                } else {
                    // E[exp(N(mu, s^2))] = exp(mu + s^2/2) = mean.
                    let mu = mean.ln() - self.sigma * self.sigma / 2.0;
                    let z = self.rng.next_gaussian();
                    (mu + self.sigma * z).exp()
                }
            }
            LatencyDist::Bimodal => {
                if self.rng.next_f64() < self.tail_frac {
                    mean * self.tail_mult
                } else {
                    // Fast mode chosen so the overall mean stays at `mean`:
                    // (1-p)*fast + p*mult*mean = mean.
                    mean * (1.0 - self.tail_frac * self.tail_mult) / (1.0 - self.tail_frac)
                }
            }
        };
        // Guard pathological samples (e.g. huge sigma) without moving the
        // mean in any realistic configuration.
        (sample.round() as u64).min(self.mean_cycles.saturating_mul(1000).max(1))
    }

    fn access(&mut self, cycle: u64, addr: u64, bytes: usize, is_write: bool) -> FarTiming {
        self.inflight += 1;
        let req_payload = if is_write { bytes } else { 0 };
        let depart = self.front.depart_request(cycle, req_payload);
        let rtt = self.sample_rtt();
        let arrive = depart + rtt / 2;
        let lines = bytes.div_ceil(64).max(1);
        let mut remote_done = arrive;
        for l in 0..lines {
            remote_done =
                remote_done.max(self.remote.service(arrive, addr + (l * 64) as u64, is_write));
        }
        let resp_payload = if is_write { 0 } else { bytes };
        let resp_depart = self.front.depart_response(remote_done, resp_payload);
        FarTiming { done: resp_depart + (rtt - rtt / 2) }
    }
}

impl FarBackend for DistributionBackend {
    fn kind(&self) -> FarBackendKind {
        FarBackendKind::Distribution
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, false)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, true)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        let depart = self.front.depart_request(cycle, bytes);
        let rtt = self.sample_rtt();
        self.remote.service(depart + rtt / 2, addr, true);
    }

    fn complete(&mut self) {
        debug_assert!(self.inflight > 0);
        self.inflight -= 1;
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn min_round_trip(&self) -> u64 {
        self.mean_cycles
    }
}

// ----------------------------------------------------------------- hybrid

/// Fast-path/slow-path split: a `near_frac` fraction of accesses is served
/// by a near tier (local cache of far pages, RDMA-cached, swap-resident),
/// the rest traverse the full serial link.
pub struct HybridBackend {
    far: FarLink,
    rng: Xoshiro256,
    near_cycles: u64,
    near_frac: f64,
    /// Tracked at this level for both paths; the inner link's own counter
    /// is cancelled right after issue.
    inflight: u64,
    pub near_hits: u64,
    pub far_misses: u64,
}

impl HybridBackend {
    pub fn new(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Self {
        Self {
            far: FarLink::new(cfg, freq_ghz, seed),
            rng: Xoshiro256::new(seed ^ 0x42B1_D000),
            near_cycles: crate::util::ns_to_cycles(cfg.near_latency_ns, freq_ghz).max(1),
            near_frac: cfg.near_frac,
            inflight: 0,
            near_hits: 0,
            far_misses: 0,
        }
    }

    #[inline]
    fn near(&mut self) -> bool {
        self.rng.next_f64() < self.near_frac
    }

    fn access(&mut self, cycle: u64, addr: u64, bytes: usize, is_write: bool) -> FarTiming {
        self.inflight += 1;
        if self.near() {
            self.near_hits += 1;
            FarTiming { done: cycle + self.near_cycles }
        } else {
            self.far_misses += 1;
            let t = if is_write {
                self.far.write(cycle, addr, bytes)
            } else {
                self.far.read(cycle, addr, bytes)
            };
            // In-flight is tracked at the hybrid level (a completion can't
            // tell which path it took); undo the inner link's increment.
            FarLink::complete(&mut self.far);
            t
        }
    }
}

impl FarBackend for HybridBackend {
    fn kind(&self) -> FarBackendKind {
        FarBackendKind::Hybrid
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, false)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, true)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        if self.near() {
            self.near_hits += 1;
        } else {
            self.far_misses += 1;
            self.far.posted_write(cycle, addr, bytes);
        }
    }

    fn complete(&mut self) {
        debug_assert!(self.inflight > 0);
        self.inflight -= 1;
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn min_round_trip(&self) -> u64 {
        FarLink::min_round_trip(&self.far)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FarMemConfig;

    fn cfg(backend: FarBackendKind) -> FarMemConfig {
        let mut c = FarMemConfig::default();
        c.added_latency_ns = 1000.0; // 3000-cycle mean RTT @3GHz
        c.jitter_frac = 0.0;
        c.backend = backend;
        c
    }

    fn mean_read_latency(b: &mut dyn FarBackend, n: u64, spacing: u64) -> f64 {
        let mut sum = 0u64;
        for i in 0..n {
            let cycle = i * spacing;
            sum += b.read(cycle, i * 4096, 64).done - cycle;
            b.complete();
        }
        sum as f64 / n as f64
    }

    #[test]
    fn build_selects_every_kind() {
        for &k in FarBackendKind::ALL {
            let b = build(&cfg(k), 3.0, 7);
            assert_eq!(b.kind(), k, "{k:?}");
            assert!(b.min_round_trip() >= 1500, "{k:?}");
        }
    }

    #[test]
    fn backends_are_deterministic_per_seed() {
        for &k in FarBackendKind::ALL {
            let mut a = build(&cfg(k), 3.0, 11);
            let mut b = build(&cfg(k), 3.0, 11);
            for i in 0..200u64 {
                let ta = a.read(i * 50, i * 64, 64).done;
                let tb = b.read(i * 50, i * 64, 64).done;
                assert_eq!(ta, tb, "{k:?} must be deterministic per seed");
            }
        }
    }

    #[test]
    fn inflight_tracks_on_all_backends() {
        for &k in FarBackendKind::ALL {
            let mut b = build(&cfg(k), 3.0, 3);
            for i in 0..10u64 {
                b.read(0, i * 4096, 64);
            }
            assert_eq!(b.inflight(), 10, "{k:?}");
            for _ in 0..10 {
                b.complete();
            }
            assert_eq!(b.inflight(), 0, "{k:?}");
        }
    }

    #[test]
    fn distribution_mean_matches_configured_latency() {
        for dist in [LatencyDist::Lognormal, LatencyDist::Bimodal] {
            let mut c = cfg(FarBackendKind::Distribution);
            c.dist = dist;
            let mut b = DistributionBackend::new(&c, 3.0, 5);
            let mut s = DistributionBackend::new(&c, 3.0, 5);
            s.sigma = 0.0;
            s.tail_frac = 0.0;
            let mean_var = mean_read_latency(&mut b, 4000, 30_000);
            let mean_det = mean_read_latency(&mut s, 4000, 30_000);
            // Lognormal(sigma=0.5) around a 3000-cycle mean has std
            // ~1600 cycles; the standard error over 4000 draws is ~25, so
            // a 5% band (150 cycles, ~6 sigma) is comfortably beyond noise
            // while still catching any systematic mean shift.
            assert!(
                (mean_var - mean_det).abs() < 0.05 * 3000.0,
                "{dist:?}: mean {mean_var:.0} vs deterministic {mean_det:.0}"
            );
        }
    }

    #[test]
    fn distribution_has_heavier_tail_than_serial_link() {
        let mut c = cfg(FarBackendKind::Distribution);
        c.dist = LatencyDist::Bimodal;
        let mut b = DistributionBackend::new(&c, 3.0, 5);
        let mut max = 0u64;
        let mut min = u64::MAX;
        for i in 0..2000u64 {
            let cycle = i * 30_000;
            let d = b.read(cycle, i * 4096, 64).done - cycle;
            b.complete();
            max = max.max(d);
            min = min.min(d);
        }
        // Slow mode is 5x the mean: the spread must show it.
        assert!(max > 3 * min, "bimodal spread too small: [{min}, {max}]");
    }

    #[test]
    fn pooled_backpressures_when_channels_congest() {
        let mut c = cfg(FarBackendKind::Pooled);
        c.pool_channels = 1;
        c.pool_queue_depth = 2;
        let mut narrow = PooledBackend::new(&c, 3.0, 1);
        // Slam one channel with simultaneous requests: beyond the queue
        // depth, arrivals must wait for older requests to drain.
        let mut last = 0;
        for i in 0..64u64 {
            last = narrow.read(0, i * 4096, 64).done;
            narrow.complete();
        }
        assert!(narrow.congestion_events() > 0, "queue depth 2 must congest");

        c.pool_channels = 8;
        c.pool_queue_depth = 16;
        let mut wide = PooledBackend::new(&c, 3.0, 1);
        let mut last_wide = 0;
        for i in 0..64u64 {
            last_wide = wide.read(0, i * 4096, 64).done;
            wide.complete();
        }
        assert!(
            last_wide <= last,
            "8 channels ({last_wide}) must not be slower than 1 congested channel ({last})"
        );
    }

    #[test]
    fn hybrid_near_fraction_speeds_up_mean() {
        let mut c = cfg(FarBackendKind::Hybrid);
        c.near_frac = 0.5;
        c.near_latency_ns = 100.0;
        let mut h = HybridBackend::new(&c, 3.0, 9);
        let mean_h = mean_read_latency(&mut h, 2000, 30_000);
        assert!(h.near_hits > 600 && h.far_misses > 600, "both paths must be taken");

        let mut serial = build(&cfg(FarBackendKind::SerialLink), 3.0, 9);
        let mean_s = mean_read_latency(serial.as_mut(), 2000, 30_000);
        // Half the accesses complete in ~300 cycles instead of ~3000+.
        assert!(
            mean_h < 0.75 * mean_s,
            "hybrid mean {mean_h:.0} must beat serial mean {mean_s:.0}"
        );
    }

    #[test]
    fn hybrid_extremes_degenerate_cleanly() {
        let mut c = cfg(FarBackendKind::Hybrid);
        c.near_frac = 1.0;
        let mut all_near = HybridBackend::new(&c, 3.0, 2);
        let t = all_near.read(0, 0, 64);
        assert_eq!(t.done, 300, "pure near tier: 100ns @3GHz");
        c.near_frac = 0.0;
        let mut all_far = HybridBackend::new(&c, 3.0, 2);
        let t = all_far.read(0, 0, 64);
        assert!(t.done >= 3000, "pure far path keeps the full RTT: {}", t.done);
    }
}
