//! Pluggable far-memory data planes behind the [`FarBackend`] trait.
//!
//! The paper evaluates one scenario — a CXL-like serial link — but its
//! premise (far latency is long *and highly variable*) covers a family of
//! data planes. Each backend here is one such scenario, selectable per run
//! via `FarMemConfig::backend` and sweepable as a grid axis:
//!
//! * `serial-link` — [`FarLink`], the paper's Figure 7 model, unchanged
//!   and the default.
//! * `pooled` — a multi-channel disaggregated memory pool: every channel
//!   owns an independent remote memory controller and a bounded service
//!   queue; a full queue back-pressures new arrivals onto the oldest
//!   outstanding request (congestion, not just bandwidth, bounds tail
//!   latency). Which channel serves a request is set by `far.pool_policy`:
//!   address `hash` (default), occupancy-aware `least-loaded`,
//!   `round-robin`, or `adaptive` (starts at `hash`, switches to
//!   `least-loaded` when observed congestion over a sliding window
//!   crosses `far.pool_adapt_threshold`).
//! * `distribution` — propagation latency sampled per request from a
//!   lognormal or bimodal distribution whose *mean* is the configured
//!   added latency, so sweeps compare equal-mean scenarios that differ
//!   only in variability (zero-mean by construction, like the serial
//!   link's fixed-amplitude jitter).
//! * `hybrid` — a fast-path/slow-path split: accesses that hit a near tier
//!   complete at `near_latency_ns` while the rest traverse the full serial
//!   link (RDMA/swap hybrid data planes). With `near_capacity_lines > 0`
//!   the near tier is a real LRU capacity model whose hit rate emerges
//!   from the access stream; at the default `0` it is the legacy static
//!   `near_frac` coin-flip.
//!
//! On top of the data planes sits the *shared-backend* layer
//! ([`SharedFar`] / [`SharedFarHandle`]): the interior arbitration point
//! that lets N tenant simulators (`amu-sim mtrun`, `session::tenancy`)
//! drive **one** pooled/hybrid data plane concurrently. Each tenant holds
//! a handle tagged with its tenant index; every request passes through a
//! [`crate::config::QosPolicyKind`] admission decision (`fair-share`
//! weighted pacing, `priority` strict classes, `throttle` adaptive
//! per-tenant rate limiting) before reaching the inner backend, and the
//! arbitration counters surface as the `qos_throttle_events` /
//! `pool_steal_cycles` scenario columns.
//!
//! All randomness is drawn from per-instance [`Xoshiro256`] streams seeded
//! from the run seed, so every backend is bit-for-bit deterministic and
//! sweep CSVs stay byte-identical across `--jobs` counts.

use super::dram::Dram;
use super::link::{add_signed, FarLink, FarTiming, LinkFront};
use crate::config::{FarBackendKind, FarMemConfig, LatencyDist, PoolPolicy, QosPolicyKind};
use crate::util::prng::Xoshiro256;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Mutex};

// Scenario counters are schema-driven: the column registry lives in
// `stats::schema` (adding a metric is a table edit there plus the backend
// that produces it); re-exported here because backends are the producers.
pub use crate::stats::schema::{ScenarioCol, ScenarioStats};

/// One far-memory data plane: issues reads/writes with absolute-cycle
/// completion times and tracks in-flight requests for MLP accounting.
pub trait FarBackend: Send {
    /// Which scenario this backend models (CSV/report tagging).
    fn kind(&self) -> FarBackendKind;

    /// Issue a read of `bytes` payload starting at `cycle`; returns the
    /// absolute cycle the response data arrives back at the requester.
    /// Caller must later call [`FarBackend::complete`].
    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming;

    /// Issue a write; returns the cycle the ack arrives back.
    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming;

    /// Posted write (dirty-line writeback): no ack tracked.
    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize);

    /// Mark one tracked request complete (MLP accounting).
    fn complete(&mut self);

    /// Requests currently in flight (the Fig 9 metric).
    fn inflight(&self) -> u64;

    /// The *mean* added round-trip latency in cycles.
    fn min_round_trip(&self) -> u64;

    /// Scenario counters accumulated so far (near-tier hit/eviction,
    /// channel congestion, ...).
    fn scenario_stats(&self) -> ScenarioStats {
        ScenarioStats::default()
    }

    /// Earliest future cycle (strictly after `now`) at which this backend
    /// will change state *on its own* — e.g. a link/channel becoming free
    /// or an internally queued completion firing. The simulator's
    /// fast-forward takes the min of this across the memory stack before
    /// jumping the clock. Every data plane in this crate computes
    /// completion times eagerly at submit and schedules them on the
    /// [`super::MemSys`] event queue, so the default is "no self-driven
    /// events"; a backend with internal timers must override this.
    fn next_event_cycle(&self, _now: u64) -> Option<u64> {
        None
    }
}

/// Construct the backend selected by `cfg.backend`. When `cfg.qos_policy`
/// is not `none` the data plane is wrapped in a single-tenant [`SharedFar`]
/// arbitration point, so the QoS policies are exercisable (and sweepable as
/// a fingerprinted refinement) even outside `mtrun`: `fair-share` paces the
/// stream at its 100% bandwidth share and `throttle` can rate-limit a solo
/// stream that congests its own backend.
pub fn build(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Box<dyn FarBackend> {
    if cfg.qos_policy != QosPolicyKind::None {
        let shared = SharedFar::new(cfg, freq_ghz, seed, vec![TenantShare::default()]);
        return Box::new(SharedFar::handle(&shared, 0));
    }
    build_raw(cfg, freq_ghz, seed)
}

/// Construct the bare data plane selected by `cfg.backend`, with no QoS
/// arbitration layer ([`SharedFar`] composes this for its inner backend).
pub fn build_raw(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Box<dyn FarBackend> {
    match cfg.backend {
        FarBackendKind::SerialLink => Box::new(FarLink::new(cfg, freq_ghz, seed)),
        FarBackendKind::Pooled => Box::new(PooledBackend::new(cfg, freq_ghz, seed)),
        FarBackendKind::Distribution => Box::new(DistributionBackend::new(cfg, freq_ghz, seed)),
        FarBackendKind::Hybrid => Box::new(HybridBackend::new(cfg, freq_ghz, seed)),
    }
}

impl FarBackend for FarLink {
    fn kind(&self) -> FarBackendKind {
        FarBackendKind::SerialLink
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        FarLink::read(self, cycle, addr, bytes)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        FarLink::write(self, cycle, addr, bytes)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        FarLink::posted_write(self, cycle, addr, bytes)
    }

    fn complete(&mut self) {
        FarLink::complete(self)
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn min_round_trip(&self) -> u64 {
        FarLink::min_round_trip(self)
    }
}

// The per-direction link front end (serialization + propagation + jitter)
// is [`LinkFront`] in `mem::link`, composed by `FarLink` and the pooled and
// distribution backends alike — the backends differ from the serial link
// only in the part they model differently.
//
// (Per-request read/write/byte counters live in the global `Stats`; the
// backends only track in-flight counts for MLP accounting.)

// ------------------------------------------------------------------ pooled

/// One channel of the disaggregated pool: an independent remote memory
/// controller plus a bounded outstanding-request queue.
struct Channel {
    remote: Dram,
    /// Completion cycles of requests this channel is still servicing, in
    /// issue order (service starts are monotone, so this stays sorted
    /// closely enough for drain-the-front bookkeeping).
    busy: VecDeque<u64>,
    depth: usize,
    congested: u64,
    served: u64,
}

impl Channel {
    /// Remaining busy cycles queued on this channel as of `at` — the
    /// occupancy-weighted load the `least-loaded` policy minimizes.
    /// Already-drained entries (done <= at) contribute zero, so no eager
    /// front-drain is needed before comparing channels.
    fn load_at(&self, at: u64) -> u64 {
        self.busy.iter().map(|&d| d.saturating_sub(at)).sum()
    }

    /// Service `lines` cache lines arriving at `at`. When the channel's
    /// queue is full the request waits for the oldest outstanding one to
    /// drain first — congestion back-pressure, the pool's signature
    /// behaviour.
    fn service(&mut self, at: u64, addr: u64, lines: usize, is_write: bool) -> u64 {
        self.served += 1;
        while self.busy.front().is_some_and(|&d| d <= at) {
            self.busy.pop_front();
        }
        let start = if self.busy.len() >= self.depth {
            self.congested += 1;
            let head = self.busy.pop_front().unwrap_or(at);
            head.max(at)
        } else {
            at
        };
        let mut done = start;
        for l in 0..lines {
            done = done.max(self.remote.service(start, addr + (l * 64) as u64, is_write));
        }
        self.busy.push_back(done);
        done
    }
}

/// Multi-channel disaggregated memory pool behind a serial link front end
/// (including the link's zero-mean propagation jitter, so the pool differs
/// from `serial-link` only in its remote side). Which channel serves a
/// request is decided by `cfg.pool_policy` at issue time; the `adaptive`
/// policy starts as `hash` and switches to `least-loaded` once the
/// congestion fraction over a sliding window of recent requests crosses
/// `cfg.pool_adapt_threshold` — a feedback decision driven purely by the
/// request stream, so it is bit-for-bit deterministic per seed.
pub struct PooledBackend {
    front: LinkFront,
    channels: Vec<Channel>,
    policy: PoolPolicy,
    /// `round-robin` rotation cursor.
    rr_next: usize,
    /// `adaptive`: the policy currently in effect (starts at `hash`,
    /// flips to `least-loaded` on sustained congestion; one-way).
    adaptive_mode: PoolPolicy,
    /// `adaptive`: per-request congestion observations, newest at the back.
    adapt_window: VecDeque<bool>,
    adapt_window_cap: usize,
    /// `adaptive`: congested entries currently in the window.
    adapt_congested: usize,
    adapt_threshold: f64,
    /// Times the adaptive policy switched (0 or 1; the switch is one-way).
    switches: u64,
    rng: Xoshiro256,
    inflight: u64,
}

impl PooledBackend {
    pub fn new(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Self {
        let n = cfg.pool_channels.max(1);
        Self {
            front: LinkFront::new(cfg, freq_ghz),
            channels: (0..n)
                .map(|_| Channel {
                    remote: Dram::new(&cfg.remote_dram, freq_ghz),
                    busy: VecDeque::new(),
                    depth: cfg.pool_queue_depth.max(1),
                    congested: 0,
                    served: 0,
                })
                .collect(),
            policy: cfg.pool_policy,
            rr_next: 0,
            adaptive_mode: PoolPolicy::Hash,
            adapt_window: VecDeque::new(),
            adapt_window_cap: cfg.pool_adapt_window.max(1),
            adapt_congested: 0,
            adapt_threshold: cfg.pool_adapt_threshold,
            switches: 0,
            rng: Xoshiro256::new(seed ^ 0x900_1ED),
            inflight: 0,
        }
    }

    /// Requests delayed by a full channel queue (observability/tests).
    pub fn congestion_events(&self) -> u64 {
        self.channels.iter().map(|c| c.congested).sum()
    }

    /// Per-channel served-request counts (load-spread observability/tests).
    pub fn channel_served(&self) -> Vec<u64> {
        self.channels.iter().map(|c| c.served).collect()
    }

    /// Times the adaptive policy switched hash -> least-loaded.
    pub fn policy_switches(&self) -> u64 {
        self.switches
    }

    /// The channel-selection policy currently in effect (`adaptive`
    /// resolves to whichever mode it is running in).
    fn effective_policy(&self) -> PoolPolicy {
        match self.policy {
            PoolPolicy::Adaptive => self.adaptive_mode,
            p => p,
        }
    }

    /// Feed one request's congestion outcome into the adaptive window and
    /// switch to `least-loaded` once the observed congestion fraction over
    /// a *full* window crosses the threshold. The switch is one-way: the
    /// affinity lost by rebalancing can't be recovered by flapping back.
    fn observe_congestion(&mut self, congested: bool) {
        if self.policy != PoolPolicy::Adaptive || self.adaptive_mode != PoolPolicy::Hash {
            return;
        }
        self.adapt_window.push_back(congested);
        self.adapt_congested += congested as usize;
        if self.adapt_window.len() > self.adapt_window_cap
            && self.adapt_window.pop_front() == Some(true)
        {
            self.adapt_congested -= 1;
        }
        if self.adapt_window.len() == self.adapt_window_cap
            && self.adapt_congested as f64 >= self.adapt_threshold * self.adapt_window_cap as f64
        {
            self.adaptive_mode = PoolPolicy::LeastLoaded;
            self.switches += 1;
            self.adapt_window.clear();
            self.adapt_congested = 0;
        }
    }

    /// Select the channel for a request to `addr` arriving at `at`,
    /// according to the policy in effect. Deterministic for a given
    /// request stream, so sweep CSVs stay byte-identical across `--jobs`.
    fn pick_channel(&mut self, at: u64, addr: u64) -> usize {
        match self.effective_policy() {
            PoolPolicy::Hash => {
                // Multiplicative hash so strided access patterns spread
                // across channels instead of aliasing onto one.
                (((addr >> 6).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize)
                    % self.channels.len()
            }
            PoolPolicy::RoundRobin => {
                let ch = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.channels.len();
                ch
            }
            PoolPolicy::LeastLoaded => {
                let mut best = 0;
                let mut best_load = u64::MAX;
                for (i, ch) in self.channels.iter().enumerate() {
                    let load = ch.load_at(at);
                    if load < best_load {
                        best_load = load;
                        best = i;
                    }
                }
                best
            }
            // `effective_policy` never returns Adaptive.
            PoolPolicy::Adaptive => unreachable!("adaptive resolves to a concrete mode"),
        }
    }

    /// Route one request through the pool: pick a channel, service it, and
    /// feed the congestion outcome back into the adaptive window.
    fn route(&mut self, arrive: u64, addr: u64, lines: usize, is_write: bool) -> u64 {
        let ch = self.pick_channel(arrive, addr);
        let before = self.channels[ch].congested;
        let remote_done = self.channels[ch].service(arrive, addr, lines, is_write);
        let congested = self.channels[ch].congested > before;
        self.observe_congestion(congested);
        remote_done
    }

    fn access(&mut self, cycle: u64, addr: u64, bytes: usize, is_write: bool) -> FarTiming {
        self.inflight += 1;
        let req_payload = if is_write { bytes } else { 0 };
        let depart = self.front.depart_request(cycle, req_payload);
        let jitter = self.front.jitter(&mut self.rng);
        let arrive = add_signed(depart + self.front.req_way_cycles(), jitter).max(depart);
        let lines = bytes.div_ceil(64).max(1);
        let remote_done = self.route(arrive, addr, lines, is_write);
        let resp_payload = if is_write { 0 } else { bytes };
        let resp_depart = self.front.depart_response(remote_done, resp_payload);
        FarTiming { done: resp_depart + self.front.resp_way_cycles() }
    }
}

impl FarBackend for PooledBackend {
    fn kind(&self) -> FarBackendKind {
        FarBackendKind::Pooled
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, false)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, true)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        let depart = self.front.depart_request(cycle, bytes);
        let arrive = depart + self.front.req_way_cycles();
        self.route(arrive, addr, bytes.div_ceil(64).max(1), true);
    }

    fn complete(&mut self) {
        debug_assert!(self.inflight > 0);
        self.inflight -= 1;
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn min_round_trip(&self) -> u64 {
        self.front.min_round_trip()
    }

    fn scenario_stats(&self) -> ScenarioStats {
        ScenarioStats::default()
            .with(ScenarioCol::PoolCongestion, self.congestion_events())
            .with(ScenarioCol::PoolSwitches, self.switches)
    }
}

// ------------------------------------------------------------ distribution

/// Per-request propagation latency sampled from a configured distribution
/// with mean equal to the configured added latency. `jitter_frac` is
/// deliberately ignored here: the sampled distribution *is* the
/// variability model, and layering uniform jitter on top would skew the
/// configured shape.
pub struct DistributionBackend {
    front: LinkFront,
    remote: Dram,
    rng: Xoshiro256,
    mean_cycles: u64,
    dist: LatencyDist,
    sigma: f64,
    tail_frac: f64,
    tail_mult: f64,
    inflight: u64,
}

impl DistributionBackend {
    pub fn new(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Self {
        Self {
            front: LinkFront::new(cfg, freq_ghz),
            remote: Dram::new(&cfg.remote_dram, freq_ghz),
            rng: Xoshiro256::new(seed ^ 0xD157_0B4C),
            mean_cycles: crate::util::ns_to_cycles(cfg.added_latency_ns, freq_ghz),
            dist: cfg.dist,
            sigma: cfg.dist_sigma,
            tail_frac: cfg.dist_tail_frac,
            tail_mult: cfg.dist_tail_mult,
            inflight: 0,
        }
    }

    /// Sample one round-trip propagation latency. Both families keep the
    /// mean at `mean_cycles` exactly (zero-mean variability), so sweeps
    /// compare equal-mean scenarios that differ only in shape.
    fn sample_rtt(&mut self) -> u64 {
        let mean = self.mean_cycles.max(1) as f64;
        let sample = match self.dist {
            LatencyDist::Lognormal => {
                if self.sigma == 0.0 {
                    mean
                } else {
                    // E[exp(N(mu, s^2))] = exp(mu + s^2/2) = mean.
                    let mu = mean.ln() - self.sigma * self.sigma / 2.0;
                    let z = self.rng.next_gaussian();
                    (mu + self.sigma * z).exp()
                }
            }
            LatencyDist::Bimodal => {
                if self.rng.next_f64() < self.tail_frac {
                    mean * self.tail_mult
                } else {
                    // Fast mode chosen so the overall mean stays at `mean`:
                    // (1-p)*fast + p*mult*mean = mean.
                    mean * (1.0 - self.tail_frac * self.tail_mult) / (1.0 - self.tail_frac)
                }
            }
        };
        // Guard pathological samples (e.g. huge sigma) without moving the
        // mean in any realistic configuration.
        (sample.round() as u64).min(self.mean_cycles.saturating_mul(1000).max(1))
    }

    fn access(&mut self, cycle: u64, addr: u64, bytes: usize, is_write: bool) -> FarTiming {
        self.inflight += 1;
        let req_payload = if is_write { bytes } else { 0 };
        let depart = self.front.depart_request(cycle, req_payload);
        let rtt = self.sample_rtt();
        let arrive = depart + rtt / 2;
        let lines = bytes.div_ceil(64).max(1);
        let mut remote_done = arrive;
        for l in 0..lines {
            remote_done =
                remote_done.max(self.remote.service(arrive, addr + (l * 64) as u64, is_write));
        }
        let resp_payload = if is_write { 0 } else { bytes };
        let resp_depart = self.front.depart_response(remote_done, resp_payload);
        FarTiming { done: resp_depart + (rtt - rtt / 2) }
    }
}

impl FarBackend for DistributionBackend {
    fn kind(&self) -> FarBackendKind {
        FarBackendKind::Distribution
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, false)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, true)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        let depart = self.front.depart_request(cycle, bytes);
        let rtt = self.sample_rtt();
        self.remote.service(depart + rtt / 2, addr, true);
    }

    fn complete(&mut self) {
        debug_assert!(self.inflight > 0);
        self.inflight -= 1;
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn min_round_trip(&self) -> u64 {
        self.mean_cycles
    }
}

// ----------------------------------------------------------------- hybrid

/// A fixed-capacity LRU set of cache lines — the hybrid backend's
/// near-tier occupancy model. Deterministic: lookups are keyed hashes
/// (never iterated), and eviction picks the minimum recency stamp from an
/// ordered map. Each resident line carries the absolute cycle its fill
/// completes (`ready_at`), so overlapping accesses that merge with an
/// in-flight fill wait for the data instead of being served before it
/// physically arrives.
struct LruSet {
    cap: usize,
    stamp: u64,
    /// line -> (recency stamp of its last touch, fill-ready cycle).
    by_line: HashMap<u64, (u64, u64)>,
    /// recency stamp -> line (stamps are unique; min = least recent).
    by_stamp: BTreeMap<u64, u64>,
}

impl LruSet {
    fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), stamp: 0, by_line: HashMap::new(), by_stamp: BTreeMap::new() }
    }

    /// If `line` is resident, refresh its recency and return the cycle its
    /// data is (or becomes) available.
    fn touch(&mut self, line: u64) -> Option<u64> {
        match self.by_line.get_mut(&line) {
            Some((old, ready_at)) => {
                self.by_stamp.remove(old);
                self.stamp += 1;
                *old = self.stamp;
                let ready = *ready_at;
                self.by_stamp.insert(self.stamp, line);
                Some(ready)
            }
            None => None,
        }
    }

    /// Install `line` as most-recent with its data available at
    /// `ready_at`; returns the evicted line, if any. The caller only fills
    /// on a miss, so the line must not already be resident.
    fn insert(&mut self, line: u64, ready_at: u64) -> Option<u64> {
        debug_assert!(!self.by_line.contains_key(&line), "fill of a resident line");
        self.stamp += 1;
        self.by_line.insert(line, (self.stamp, ready_at));
        self.by_stamp.insert(self.stamp, line);
        if self.by_line.len() > self.cap {
            let (_, victim) = self.by_stamp.pop_first().expect("occupied LRU");
            self.by_line.remove(&victim);
            return Some(victim);
        }
        None
    }
}

/// Fast-path/slow-path split: accesses served by a near tier (local cache
/// of far pages, RDMA-cached, swap-resident) complete at `near_latency_ns`;
/// the rest traverse the full serial link.
///
/// Two near-tier models, selected by `cfg.near_capacity_lines`:
///
/// * `0` (default) — the legacy static split: each access independently
///   lands near with probability `near_frac` (seeded coin-flip).
/// * `> 0` — a real capacity model: an LRU set of that many 64 B lines.
///   An access whose line is resident is a near hit; a miss pays the far
///   path and installs its line (evicting the least-recently-used line
///   once full), so the hit rate emerges from actual reuse. A hit on a
///   line whose fill is still in flight waits for the fill to land
///   (MSHR-like merge) — data is never served before it arrives.
pub struct HybridBackend {
    far: FarLink,
    rng: Xoshiro256,
    near_cycles: u64,
    near_frac: f64,
    /// `Some` iff the LRU capacity model is enabled.
    near: Option<LruSet>,
    /// Tracked at this level for both paths; the inner link's own counter
    /// is cancelled right after issue.
    inflight: u64,
    pub near_hits: u64,
    pub near_evictions: u64,
    pub far_misses: u64,
}

impl HybridBackend {
    pub fn new(cfg: &FarMemConfig, freq_ghz: f64, seed: u64) -> Self {
        Self {
            far: FarLink::new(cfg, freq_ghz, seed),
            rng: Xoshiro256::new(seed ^ 0x42B1_D000),
            near_cycles: crate::util::ns_to_cycles(cfg.near_latency_ns, freq_ghz).max(1),
            near_frac: cfg.near_frac,
            near: (cfg.near_capacity_lines > 0).then(|| LruSet::new(cfg.near_capacity_lines)),
            inflight: 0,
            near_hits: 0,
            near_evictions: 0,
            far_misses: 0,
        }
    }

    /// Near-tier lookup: `Some(ready)` if this access is served by the
    /// near tier, where `ready` is the cycle the line's data is available
    /// (later than `cycle` only while its fill is still in flight).
    /// Multi-line accesses are classified by their first line (the model's
    /// granularity).
    #[inline]
    fn near_ready(&mut self, cycle: u64, addr: u64) -> Option<u64> {
        match self.near.as_mut() {
            Some(lru) => lru.touch(addr >> 6),
            None => (self.rng.next_f64() < self.near_frac).then_some(cycle),
        }
    }

    /// After a far-path access: install the line in the near tier (LRU
    /// model only) with its fill completing at `ready_at`, counting any
    /// eviction. Accesses that merge with the in-flight fill wait for
    /// `ready_at` — an MSHR-like merge, not a time-traveling hit.
    #[inline]
    fn fill_near(&mut self, addr: u64, ready_at: u64) {
        if let Some(lru) = self.near.as_mut() {
            if lru.insert(addr >> 6, ready_at).is_some() {
                self.near_evictions += 1;
            }
        }
    }

    fn access(&mut self, cycle: u64, addr: u64, bytes: usize, is_write: bool) -> FarTiming {
        self.inflight += 1;
        if let Some(ready) = self.near_ready(cycle, addr) {
            self.near_hits += 1;
            FarTiming { done: ready.max(cycle) + self.near_cycles }
        } else {
            self.far_misses += 1;
            let t = if is_write {
                self.far.write(cycle, addr, bytes)
            } else {
                self.far.read(cycle, addr, bytes)
            };
            // In-flight is tracked at the hybrid level (a completion can't
            // tell which path it took); undo the inner link's increment.
            FarLink::complete(&mut self.far);
            // Write data originates locally and is readable from the near
            // tier right away (same as the posted-write path); only a read
            // fill makes later hits wait for the far data to arrive.
            self.fill_near(addr, if is_write { cycle } else { t.done });
            t
        }
    }
}

impl FarBackend for HybridBackend {
    fn kind(&self) -> FarBackendKind {
        FarBackendKind::Hybrid
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, false)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        self.access(cycle, addr, bytes, true)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        if self.near_ready(cycle, addr).is_some() {
            self.near_hits += 1;
        } else {
            self.far_misses += 1;
            self.far.posted_write(cycle, addr, bytes);
            // Write data originates locally: the line is readable from the
            // near tier right away, unlike a read fill in flight.
            self.fill_near(addr, cycle);
        }
    }

    fn complete(&mut self) {
        debug_assert!(self.inflight > 0);
        self.inflight -= 1;
    }

    fn inflight(&self) -> u64 {
        self.inflight
    }

    fn min_round_trip(&self) -> u64 {
        FarLink::min_round_trip(&self.far)
    }

    fn scenario_stats(&self) -> ScenarioStats {
        ScenarioStats::default()
            .with(ScenarioCol::NearHits, self.near_hits)
            .with(ScenarioCol::NearEvictions, self.near_evictions)
    }
}

// ------------------------------------------------------------ shared / QoS

/// Strict admission class for the `priority` QoS policy. Lower rank is
/// served first: a request admits only after every higher class's busy
/// horizon has drained.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum QosClass {
    High,
    Normal,
    Low,
}

impl QosClass {
    pub const ALL: &'static [QosClass] = &[QosClass::High, QosClass::Normal, QosClass::Low];

    /// Admission rank: 0 admits ahead of 1 ahead of 2.
    pub fn rank(self) -> usize {
        match self {
            QosClass::High => 0,
            QosClass::Normal => 1,
            QosClass::Low => 2,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            QosClass::High => "high",
            QosClass::Normal => "normal",
            QosClass::Low => "low",
        }
    }

    /// Parse a tenant-spec priority name (the `/high` part of
    /// `redis:2@3/high`).
    pub fn parse(s: &str) -> Option<QosClass> {
        match s {
            "high" | "hi" => Some(QosClass::High),
            "normal" | "norm" | "mid" => Some(QosClass::Normal),
            "low" | "lo" => Some(QosClass::Low),
            _ => None,
        }
    }
}

/// One tenant's share of the pool under QoS arbitration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantShare {
    /// Relative bandwidth weight under `fair-share` (floored to 1).
    pub weight: u64,
    /// Admission class under `priority`.
    pub class: QosClass,
}

impl Default for TenantShare {
    fn default() -> Self {
        Self { weight: 1, class: QosClass::Normal }
    }
}

/// Sum of the counters a congesting inner backend exposes — the feedback
/// signal the `throttle` policy watches (pool queue back-pressure, near-tier
/// capacity thrash).
fn congestion_signal(s: &ScenarioStats) -> u64 {
    s.get(ScenarioCol::PoolCongestion) + s.get(ScenarioCol::NearEvictions)
}

/// The shared-backend arbitration point: **one** inner data plane (built
/// via [`build_raw`]) serving N tenants, each holding a [`SharedFarHandle`]
/// tagged with its tenant index. Every request passes an admission decision
/// before reaching the inner backend:
///
/// * `none` — pure passthrough (requests admit at their issue cycle).
/// * `fair-share` — weighted pacing: each admitted request charges its
///   tenant `cost x total_weight / weight` cycles of virtual busy time, so
///   a weight-3 tenant sustains 3x the admission rate of a weight-1 tenant
///   sharing the same pool.
/// * `priority` — strict classes: a request admits only after every higher
///   class's busy horizon has drained, and each request extends its own
///   class's horizon by its service cost (low classes can starve behind a
///   high-class flood — that is the policy's contract).
/// * `throttle` — adaptive per-tenant rate limiting, generalizing the
///   pooled backend's `adaptive` policy: each tenant's requests feed a
///   sliding window of congestion observations (`pool_adapt_window` wide);
///   once the congested fraction crosses `pool_adapt_threshold` the tenant
///   is throttled (one-way, like the adaptive pool switch) and its
///   subsequent requests are spaced at least `2 x cost` apart.
///
/// The per-request service cost is `lines x unit_cost`, where `unit_cost`
/// (= mean RTT / 64, floored to 1) models the shared entry point's
/// aggregate line bandwidth. Admission delay accumulates into
/// `pool_steal_cycles`; throttle activations and enforced gaps into
/// `qos_throttle_events`. Everything is driven by the request stream alone,
/// so arbitration is bit-for-bit deterministic per seed.
///
/// In-flight counts are tracked **per tenant** at this level (the inner
/// backend's increment is cancelled right after issue, the hybrid's trick),
/// so one tenant's MLP accounting never pollutes another's.
pub struct SharedFar {
    inner: Box<dyn FarBackend>,
    policy: QosPolicyKind,
    shares: Vec<TenantShare>,
    total_weight: u64,
    /// Cycles one 64 B line occupies the shared entry point.
    unit_cost: u64,
    /// `fair-share`: per-tenant virtual busy-until cycle.
    busy_until: Vec<u64>,
    /// `priority`: per-class busy horizon, indexed by [`QosClass::rank`].
    class_busy: [u64; 3],
    /// `throttle`: per-tenant congestion observations, newest at the back.
    window: Vec<VecDeque<bool>>,
    window_congested: Vec<usize>,
    window_cap: usize,
    threshold: f64,
    /// `throttle`: per-tenant throttled flag (one-way).
    throttled: Vec<bool>,
    /// `throttle`: per-tenant earliest next admission while throttled.
    next_allowed: Vec<u64>,
    /// Last observed inner congestion signal (delta detection).
    last_signal: u64,
    steal_cycles: u64,
    throttle_events: u64,
    per_tenant_inflight: Vec<u64>,
}

impl SharedFar {
    /// Build the shared arbitration point over a freshly constructed inner
    /// data plane, with one slot per entry in `shares`.
    pub fn new(
        cfg: &FarMemConfig,
        freq_ghz: f64,
        seed: u64,
        shares: Vec<TenantShare>,
    ) -> Arc<Mutex<SharedFar>> {
        assert!(!shares.is_empty(), "shared backend needs at least one tenant");
        let inner = build_raw(cfg, freq_ghz, seed);
        let n = shares.len();
        let total_weight = shares.iter().map(|s| s.weight.max(1)).sum();
        let unit_cost = (inner.min_round_trip() / 64).max(1);
        Arc::new(Mutex::new(SharedFar {
            inner,
            policy: cfg.qos_policy,
            shares,
            total_weight,
            unit_cost,
            busy_until: vec![0; n],
            class_busy: [0; 3],
            window: vec![VecDeque::new(); n],
            window_congested: vec![0; n],
            window_cap: cfg.pool_adapt_window.max(1),
            threshold: cfg.pool_adapt_threshold,
            throttled: vec![false; n],
            next_allowed: vec![0; n],
            last_signal: 0,
            steal_cycles: 0,
            throttle_events: 0,
            per_tenant_inflight: vec![0; n],
        }))
    }

    /// A tenant's handle onto the shared backend (panics on an index with
    /// no share slot — handles and shares are created together).
    pub fn handle(shared: &Arc<Mutex<SharedFar>>, tenant: usize) -> SharedFarHandle {
        let n = shared.lock().expect("shared far-memory lock poisoned").shares.len();
        assert!(tenant < n, "tenant {tenant} out of range ({n} share slots)");
        SharedFarHandle { shared: Arc::clone(shared), tenant }
    }

    /// Total cycles requests spent waiting in QoS admission (the
    /// `pool_steal_cycles` column).
    pub fn steal_cycles(&self) -> u64 {
        self.steal_cycles
    }

    /// Throttle activations plus enforced admission gaps (the
    /// `qos_throttle_events` column).
    pub fn throttle_events(&self) -> u64 {
        self.throttle_events
    }

    /// Whether `tenant` has tripped the (one-way) throttle.
    pub fn is_throttled(&self, tenant: usize) -> bool {
        self.throttled[tenant]
    }

    /// Inner scenario counters plus the shared arbitration columns — what
    /// every tenant's handle reports (the columns are pool-wide by design;
    /// their producer is "shared").
    pub fn scenario_snapshot(&self) -> ScenarioStats {
        self.inner
            .scenario_stats()
            .with(ScenarioCol::QosThrottleEvents, self.throttle_events)
            .with(ScenarioCol::PoolStealCycles, self.steal_cycles)
    }

    /// Decide the admission cycle for `tenant`'s request of `lines` cache
    /// lines issued at `cycle`, updating the policy state. Never earlier
    /// than `cycle`.
    fn admit(&mut self, tenant: usize, cycle: u64, lines: u64) -> u64 {
        let cost = lines * self.unit_cost;
        match self.policy {
            QosPolicyKind::None => cycle,
            QosPolicyKind::FairShare => {
                let admit = cycle.max(self.busy_until[tenant]);
                let w = self.shares[tenant].weight.max(1);
                self.busy_until[tenant] = admit + cost * self.total_weight / w;
                admit
            }
            QosPolicyKind::Priority => {
                let rank = self.shares[tenant].class.rank();
                let mut admit = cycle;
                for c in 0..rank {
                    admit = admit.max(self.class_busy[c]);
                }
                self.class_busy[rank] = self.class_busy[rank].max(admit) + cost;
                admit
            }
            QosPolicyKind::Throttle => {
                if !self.throttled[tenant] {
                    return cycle;
                }
                let admit = cycle.max(self.next_allowed[tenant]);
                if admit > cycle {
                    self.throttle_events += 1;
                }
                self.next_allowed[tenant] = admit + 2 * cost;
                admit
            }
        }
    }

    /// Feed one request's congestion outcome into `tenant`'s sliding window
    /// and trip its throttle once the congested fraction over a *full*
    /// window crosses the threshold (same full-window, one-way contract as
    /// the pooled backend's adaptive switch).
    fn observe(&mut self, tenant: usize) {
        let sig = congestion_signal(&self.inner.scenario_stats());
        let congested = sig > self.last_signal;
        self.last_signal = sig;
        if self.policy != QosPolicyKind::Throttle || self.throttled[tenant] {
            return;
        }
        self.window[tenant].push_back(congested);
        self.window_congested[tenant] += congested as usize;
        if self.window[tenant].len() > self.window_cap
            && self.window[tenant].pop_front() == Some(true)
        {
            self.window_congested[tenant] -= 1;
        }
        if self.window[tenant].len() == self.window_cap
            && self.window_congested[tenant] as f64 >= self.threshold * self.window_cap as f64
        {
            self.throttled[tenant] = true;
            self.throttle_events += 1;
            self.window[tenant].clear();
            self.window_congested[tenant] = 0;
        }
    }

    fn access(&mut self, tenant: usize, cycle: u64, addr: u64, bytes: usize, is_write: bool) -> FarTiming {
        self.per_tenant_inflight[tenant] += 1;
        let lines = bytes.div_ceil(64).max(1) as u64;
        let admit = self.admit(tenant, cycle, lines);
        self.steal_cycles += admit - cycle;
        let t = if is_write {
            self.inner.write(admit, addr, bytes)
        } else {
            self.inner.read(admit, addr, bytes)
        };
        // In-flight is tracked per tenant at this level; cancel the inner
        // backend's increment right after issue (the hybrid's trick).
        self.inner.complete();
        self.observe(tenant);
        t
    }

    fn posted(&mut self, tenant: usize, cycle: u64, addr: u64, bytes: usize) {
        let lines = bytes.div_ceil(64).max(1) as u64;
        let admit = self.admit(tenant, cycle, lines);
        self.steal_cycles += admit - cycle;
        self.inner.posted_write(admit, addr, bytes);
        self.observe(tenant);
    }
}

/// One tenant's view of a [`SharedFar`]: implements [`FarBackend`], so a
/// per-tenant `Simulator` drives the shared pool through its ordinary
/// `MemSys.link` slot without knowing other tenants exist. Cloning yields
/// another handle onto the *same* shared state.
#[derive(Clone)]
pub struct SharedFarHandle {
    shared: Arc<Mutex<SharedFar>>,
    tenant: usize,
}

impl SharedFarHandle {
    fn lock(&self) -> std::sync::MutexGuard<'_, SharedFar> {
        self.shared.lock().expect("shared far-memory lock poisoned")
    }

    /// The tenant index this handle routes as.
    pub fn tenant(&self) -> usize {
        self.tenant
    }
}

impl FarBackend for SharedFarHandle {
    fn kind(&self) -> FarBackendKind {
        self.lock().inner.kind()
    }

    fn read(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        let tenant = self.tenant;
        self.lock().access(tenant, cycle, addr, bytes, false)
    }

    fn write(&mut self, cycle: u64, addr: u64, bytes: usize) -> FarTiming {
        let tenant = self.tenant;
        self.lock().access(tenant, cycle, addr, bytes, true)
    }

    fn posted_write(&mut self, cycle: u64, addr: u64, bytes: usize) {
        let tenant = self.tenant;
        self.lock().posted(tenant, cycle, addr, bytes)
    }

    fn complete(&mut self) {
        let mut s = self.lock();
        debug_assert!(s.per_tenant_inflight[self.tenant] > 0);
        s.per_tenant_inflight[self.tenant] -= 1;
    }

    fn inflight(&self) -> u64 {
        self.lock().per_tenant_inflight[self.tenant]
    }

    fn min_round_trip(&self) -> u64 {
        self.lock().inner.min_round_trip()
    }

    fn scenario_stats(&self) -> ScenarioStats {
        self.lock().scenario_snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FarMemConfig;

    fn cfg(backend: FarBackendKind) -> FarMemConfig {
        let mut c = FarMemConfig::default();
        c.added_latency_ns = 1000.0; // 3000-cycle mean RTT @3GHz
        c.jitter_frac = 0.0;
        c.backend = backend;
        c
    }

    fn mean_read_latency(b: &mut dyn FarBackend, n: u64, spacing: u64) -> f64 {
        let mut sum = 0u64;
        for i in 0..n {
            let cycle = i * spacing;
            sum += b.read(cycle, i * 4096, 64).done - cycle;
            b.complete();
        }
        sum as f64 / n as f64
    }

    #[test]
    fn build_selects_every_kind() {
        for &k in FarBackendKind::ALL {
            let b = build(&cfg(k), 3.0, 7);
            assert_eq!(b.kind(), k, "{k:?}");
            assert!(b.min_round_trip() >= 1500, "{k:?}");
        }
    }

    #[test]
    fn backends_are_deterministic_per_seed() {
        for &k in FarBackendKind::ALL {
            let mut a = build(&cfg(k), 3.0, 11);
            let mut b = build(&cfg(k), 3.0, 11);
            for i in 0..200u64 {
                let ta = a.read(i * 50, i * 64, 64).done;
                let tb = b.read(i * 50, i * 64, 64).done;
                assert_eq!(ta, tb, "{k:?} must be deterministic per seed");
            }
        }
    }

    #[test]
    fn inflight_tracks_on_all_backends() {
        for &k in FarBackendKind::ALL {
            let mut b = build(&cfg(k), 3.0, 3);
            for i in 0..10u64 {
                b.read(0, i * 4096, 64);
            }
            assert_eq!(b.inflight(), 10, "{k:?}");
            for _ in 0..10 {
                b.complete();
            }
            assert_eq!(b.inflight(), 0, "{k:?}");
        }
    }

    #[test]
    fn distribution_mean_matches_configured_latency() {
        for dist in [LatencyDist::Lognormal, LatencyDist::Bimodal] {
            let mut c = cfg(FarBackendKind::Distribution);
            c.dist = dist;
            let mut b = DistributionBackend::new(&c, 3.0, 5);
            let mut s = DistributionBackend::new(&c, 3.0, 5);
            s.sigma = 0.0;
            s.tail_frac = 0.0;
            let mean_var = mean_read_latency(&mut b, 4000, 30_000);
            let mean_det = mean_read_latency(&mut s, 4000, 30_000);
            // Lognormal(sigma=0.5) around a 3000-cycle mean has std
            // ~1600 cycles; the standard error over 4000 draws is ~25, so
            // a 5% band (150 cycles, ~6 sigma) is comfortably beyond noise
            // while still catching any systematic mean shift.
            assert!(
                (mean_var - mean_det).abs() < 0.05 * 3000.0,
                "{dist:?}: mean {mean_var:.0} vs deterministic {mean_det:.0}"
            );
        }
    }

    #[test]
    fn distribution_has_heavier_tail_than_serial_link() {
        let mut c = cfg(FarBackendKind::Distribution);
        c.dist = LatencyDist::Bimodal;
        let mut b = DistributionBackend::new(&c, 3.0, 5);
        let mut max = 0u64;
        let mut min = u64::MAX;
        for i in 0..2000u64 {
            let cycle = i * 30_000;
            let d = b.read(cycle, i * 4096, 64).done - cycle;
            b.complete();
            max = max.max(d);
            min = min.min(d);
        }
        // Slow mode is 5x the mean: the spread must show it.
        assert!(max > 3 * min, "bimodal spread too small: [{min}, {max}]");
    }

    #[test]
    fn pooled_backpressures_when_channels_congest() {
        let mut c = cfg(FarBackendKind::Pooled);
        c.pool_channels = 1;
        c.pool_queue_depth = 2;
        let mut narrow = PooledBackend::new(&c, 3.0, 1);
        // Slam one channel with simultaneous requests: beyond the queue
        // depth, arrivals must wait for older requests to drain.
        let mut last = 0;
        for i in 0..64u64 {
            last = narrow.read(0, i * 4096, 64).done;
            narrow.complete();
        }
        assert!(narrow.congestion_events() > 0, "queue depth 2 must congest");

        c.pool_channels = 8;
        c.pool_queue_depth = 16;
        let mut wide = PooledBackend::new(&c, 3.0, 1);
        let mut last_wide = 0;
        for i in 0..64u64 {
            last_wide = wide.read(0, i * 4096, 64).done;
            wide.complete();
        }
        assert!(
            last_wide <= last,
            "8 channels ({last_wide}) must not be slower than 1 congested channel ({last})"
        );
    }

    #[test]
    fn min_round_trip_matches_configured_latency_exactly() {
        // Regression for the LinkFront fold: every backend that models the
        // configured RTT must report it exactly, including odd cycle counts
        // (333 ns @3GHz = 999 cycles — a naive added/2 split drops one).
        for &ns in &[333.0, 1000.0] {
            let cycles = crate::util::ns_to_cycles(ns, 3.0);
            for &k in FarBackendKind::ALL {
                let mut c = cfg(k);
                c.added_latency_ns = ns;
                let b = build(&c, 3.0, 1);
                assert_eq!(b.min_round_trip(), cycles, "{k:?} @{ns}ns");
            }
        }
    }

    #[test]
    fn least_loaded_spreads_a_hot_address_stream() {
        // Every request targets the same line, so the hash policy pins the
        // whole stream to one channel while the others idle; least-loaded
        // must spread it and finish no later.
        let mut c = cfg(FarBackendKind::Pooled);
        c.pool_channels = 4;
        c.pool_queue_depth = 2;

        let mut hashed = PooledBackend::new(&c, 3.0, 1);
        let mut last_hash = 0;
        for _ in 0..32 {
            last_hash = hashed.read(0, 0, 64).done;
            hashed.complete();
        }
        let hash_served = hashed.channel_served();
        assert_eq!(
            hash_served.iter().filter(|&&n| n > 0).count(),
            1,
            "hash must pin one address to one channel: {hash_served:?}"
        );

        c.pool_policy = PoolPolicy::LeastLoaded;
        let mut balanced = PooledBackend::new(&c, 3.0, 1);
        let mut last_ll = 0;
        for _ in 0..32 {
            last_ll = balanced.read(0, 0, 64).done;
            balanced.complete();
        }
        let ll_served = balanced.channel_served();
        assert!(
            ll_served.iter().all(|&n| n > 0),
            "least-loaded must use every channel: {ll_served:?}"
        );
        assert!(
            last_ll <= last_hash,
            "spreading ({last_ll}) must not be slower than one hot channel ({last_hash})"
        );
        assert!(
            balanced.congestion_events() <= hashed.congestion_events(),
            "spreading must not congest more ({} vs {})",
            balanced.congestion_events(),
            hashed.congestion_events()
        );
    }

    #[test]
    fn round_robin_rotates_channels_evenly() {
        let mut c = cfg(FarBackendKind::Pooled);
        c.pool_channels = 4;
        c.pool_policy = PoolPolicy::RoundRobin;
        let mut p = PooledBackend::new(&c, 3.0, 1);
        for i in 0..8u64 {
            p.read(i * 10, 0, 64);
            p.complete();
        }
        assert_eq!(p.channel_served(), vec![2, 2, 2, 2]);
    }

    #[test]
    fn pool_policies_are_deterministic_per_seed() {
        for &policy in PoolPolicy::ALL {
            let mut c = cfg(FarBackendKind::Pooled);
            c.jitter_frac = 0.05;
            c.pool_policy = policy;
            let mut a = PooledBackend::new(&c, 3.0, 11);
            let mut b = PooledBackend::new(&c, 3.0, 11);
            for i in 0..200u64 {
                // A mildly skewed stream: half the accesses hit line 0.
                let addr = if i % 2 == 0 { 0 } else { i * 4096 };
                assert_eq!(
                    a.read(i * 50, addr, 64).done,
                    b.read(i * 50, addr, 64).done,
                    "{policy:?} must be deterministic per seed"
                );
            }
        }
    }

    #[test]
    fn hybrid_lru_evicts_in_recency_order_and_counts_hits() {
        let mut c = cfg(FarBackendKind::Hybrid);
        c.near_capacity_lines = 2;
        c.near_latency_ns = 100.0; // 300 cycles @3GHz
        let mut h = HybridBackend::new(&c, 3.0, 9);
        let (a, b, d) = (0u64, 64u64, 128u64);

        h.read(0, a, 64); // miss: install A
        h.complete();
        h.read(10_000, b, 64); // miss: install B
        h.complete();
        let t = h.read(20_000, a, 64); // hit: A resident, refreshed
        h.complete();
        assert_eq!(t.done, 20_000 + 300, "near hit must cost exactly the near latency");
        h.read(30_000, d, 64); // miss: evicts B (A is more recent)
        h.complete();
        let t = h.read(40_000, a, 64); // still a hit: A survived the eviction
        h.complete();
        assert_eq!(t.done, 40_000 + 300);
        let t = h.read(50_000, b, 64); // miss: B was the LRU victim
        h.complete();
        assert!(t.done - 50_000 >= 3000, "evicted line must pay the far path: {}", t.done);

        assert_eq!(h.near_hits, 2);
        assert_eq!(h.near_evictions, 2, "B then D evicted");
        assert_eq!(h.far_misses, 4);
        assert_eq!(
            h.scenario_stats(),
            ScenarioStats::default()
                .with(ScenarioCol::NearHits, 2)
                .with(ScenarioCol::NearEvictions, 2)
        );
    }

    #[test]
    fn hybrid_overlapping_accesses_wait_for_the_inflight_fill() {
        // High-MLP regime: a second access to a line whose fill is still
        // in flight merges with it (a near hit), but cannot complete
        // before the far data physically arrives.
        let mut c = cfg(FarBackendKind::Hybrid);
        c.near_capacity_lines = 8;
        c.near_latency_ns = 100.0; // 300 cycles @3GHz
        let mut h = HybridBackend::new(&c, 3.0, 9);
        let fill = h.read(0, 0, 64); // cold miss; data lands at fill.done
        h.complete();
        let t = h.read(10, 0, 64); // overlaps the in-flight fill
        h.complete();
        assert_eq!(h.near_hits, 1, "merge counts as a near hit");
        assert_eq!(t.done, fill.done + 300, "merge must wait for the fill");
        // Once the fill has landed, hits cost exactly the near latency.
        let t = h.read(fill.done + 1000, 0, 64);
        h.complete();
        assert_eq!(t.done, fill.done + 1000 + 300);
    }

    #[test]
    fn hybrid_write_fill_is_readable_immediately() {
        // Write data originates locally: a read right after a far write
        // miss to the same line is a near hit at the near latency, not
        // stalled on the write ack (consistent with the posted-write path).
        let mut c = cfg(FarBackendKind::Hybrid);
        c.near_capacity_lines = 8;
        c.near_latency_ns = 100.0; // 300 cycles @3GHz
        let mut h = HybridBackend::new(&c, 3.0, 9);
        let ack = h.write(0, 0, 64); // far write; ack returns ~RTT later
        h.complete();
        assert!(ack.done >= 3000);
        let t = h.read(10, 0, 64);
        h.complete();
        assert_eq!(t.done, 10 + 300, "local write data must not wait for the ack");
    }

    #[test]
    fn hybrid_capacity_model_hit_rate_tracks_reuse() {
        // Working set fits: after the cold pass, every access is a near
        // hit. No coin-flip involved — the hit rate is a property of the
        // stream, not of `near_frac` (deliberately set to 0 here).
        let mut c = cfg(FarBackendKind::Hybrid);
        c.near_capacity_lines = 64;
        c.near_frac = 0.0;
        let mut h = HybridBackend::new(&c, 3.0, 5);
        for pass in 0..4u64 {
            for line in 0..64u64 {
                h.read(pass * 1_000_000 + line * 10_000, line * 64, 64);
                h.complete();
            }
        }
        assert_eq!(h.far_misses, 64, "only the cold pass misses");
        assert_eq!(h.near_hits, 3 * 64);
        assert_eq!(h.near_evictions, 0);
    }

    #[test]
    fn scenario_stats_default_to_zero_on_backends_without_the_mechanism() {
        for &k in [FarBackendKind::SerialLink, FarBackendKind::Distribution].iter() {
            let mut b = build(&cfg(k), 3.0, 3);
            b.read(0, 0, 64);
            b.complete();
            assert_eq!(b.scenario_stats(), ScenarioStats::default(), "{k:?}");
        }
        // And the pooled backend surfaces congestion through the trait.
        let mut c = cfg(FarBackendKind::Pooled);
        c.pool_channels = 1;
        c.pool_queue_depth = 1;
        let mut p = PooledBackend::new(&c, 3.0, 1);
        for i in 0..16u64 {
            p.read(0, i * 4096, 64);
            p.complete();
        }
        assert!(p.scenario_stats().get(ScenarioCol::PoolCongestion) > 0);
    }

    #[test]
    fn adaptive_policy_switches_under_sustained_congestion() {
        // One hot line through a shallow 4-channel pool: hash pins the
        // stream to one channel, congestion builds, and the adaptive
        // policy must flip to least-loaded and start spreading.
        let mut c = cfg(FarBackendKind::Pooled);
        c.pool_channels = 4;
        c.pool_queue_depth = 2;
        c.pool_policy = PoolPolicy::Adaptive;
        c.pool_adapt_threshold = 0.5;
        c.pool_adapt_window = 8;
        let mut p = PooledBackend::new(&c, 3.0, 1);
        for _ in 0..64 {
            p.read(0, 0, 64);
            p.complete();
        }
        assert_eq!(p.policy_switches(), 1, "sustained congestion must trigger the switch");
        assert_eq!(p.scenario_stats().get(ScenarioCol::PoolSwitches), 1);
        let served = p.channel_served();
        assert!(
            served.iter().filter(|&&n| n > 0).count() > 1,
            "post-switch requests must spread beyond the hash channel: {served:?}"
        );

        // An uncongested stream (deep queues, spread addresses) never
        // switches: adaptive degenerates to hash exactly.
        let mut c2 = cfg(FarBackendKind::Pooled);
        c2.pool_channels = 4;
        c2.pool_queue_depth = 64;
        c2.pool_policy = PoolPolicy::Adaptive;
        let mut calm = PooledBackend::new(&c2, 3.0, 1);
        c2.pool_policy = PoolPolicy::Hash;
        let mut hash = PooledBackend::new(&c2, 3.0, 1);
        for i in 0..64u64 {
            let (a, b) = (
                calm.read(i * 20_000, i * 4096, 64).done,
                hash.read(i * 20_000, i * 4096, 64).done,
            );
            calm.complete();
            hash.complete();
            assert_eq!(a, b, "uncongested adaptive must behave exactly like hash");
        }
        assert_eq!(calm.policy_switches(), 0);
    }

    #[test]
    fn adaptive_policy_is_deterministic_per_seed() {
        let mut c = cfg(FarBackendKind::Pooled);
        c.jitter_frac = 0.05;
        c.pool_channels = 4;
        c.pool_queue_depth = 2;
        c.pool_policy = PoolPolicy::Adaptive;
        c.pool_adapt_window = 8;
        let mut a = PooledBackend::new(&c, 3.0, 11);
        let mut b = PooledBackend::new(&c, 3.0, 11);
        for i in 0..200u64 {
            let addr = if i % 2 == 0 { 0 } else { i * 4096 };
            assert_eq!(
                a.read(i * 50, addr, 64).done,
                b.read(i * 50, addr, 64).done,
                "adaptive must be deterministic per seed"
            );
        }
        assert_eq!(a.policy_switches(), b.policy_switches());
    }

    #[test]
    fn hybrid_near_fraction_speeds_up_mean() {
        let mut c = cfg(FarBackendKind::Hybrid);
        c.near_frac = 0.5;
        c.near_latency_ns = 100.0;
        let mut h = HybridBackend::new(&c, 3.0, 9);
        let mean_h = mean_read_latency(&mut h, 2000, 30_000);
        assert!(h.near_hits > 600 && h.far_misses > 600, "both paths must be taken");

        let mut serial = build(&cfg(FarBackendKind::SerialLink), 3.0, 9);
        let mean_s = mean_read_latency(serial.as_mut(), 2000, 30_000);
        // Half the accesses complete in ~300 cycles instead of ~3000+.
        assert!(
            mean_h < 0.75 * mean_s,
            "hybrid mean {mean_h:.0} must beat serial mean {mean_s:.0}"
        );
    }

    #[test]
    fn hybrid_extremes_degenerate_cleanly() {
        let mut c = cfg(FarBackendKind::Hybrid);
        c.near_frac = 1.0;
        let mut all_near = HybridBackend::new(&c, 3.0, 2);
        let t = all_near.read(0, 0, 64);
        assert_eq!(t.done, 300, "pure near tier: 100ns @3GHz");
        c.near_frac = 0.0;
        let mut all_far = HybridBackend::new(&c, 3.0, 2);
        let t = all_far.read(0, 0, 64);
        assert!(t.done >= 3000, "pure far path keeps the full RTT: {}", t.done);
    }

    // ------------------------------------------------------ shared / QoS

    fn qos_cfg(policy: QosPolicyKind) -> FarMemConfig {
        let mut c = cfg(FarBackendKind::Pooled);
        c.qos_policy = policy;
        c
    }

    fn shares(n: usize) -> Vec<TenantShare> {
        vec![TenantShare::default(); n]
    }

    #[test]
    fn qos_class_tags_and_ranks_are_ordered() {
        for (i, &c) in QosClass::ALL.iter().enumerate() {
            assert_eq!(c.rank(), i);
            assert_eq!(QosClass::parse(c.tag()), Some(c));
        }
        assert_eq!(QosClass::parse("hi"), Some(QosClass::High));
        assert_eq!(QosClass::parse("urgent"), None);
    }

    #[test]
    fn shared_handle_with_no_policy_is_a_pure_passthrough() {
        let c = cfg(FarBackendKind::Pooled);
        let mut raw = build_raw(&c, 3.0, 11);
        let shared = SharedFar::new(&c, 3.0, 11, shares(1));
        let mut h = SharedFar::handle(&shared, 0);
        for i in 0..100u64 {
            let a = raw.read(i * 50, i * 64, 64).done;
            raw.complete();
            let b = h.read(i * 50, i * 64, 64).done;
            h.complete();
            assert_eq!(a, b, "qos=none must not perturb timing");
        }
        assert_eq!(shared.lock().unwrap().steal_cycles(), 0);
    }

    #[test]
    fn build_wraps_in_a_shared_arbiter_when_qos_is_set() {
        let c = qos_cfg(QosPolicyKind::FairShare);
        let mut b = build(&c, 3.0, 7);
        // The wrapper is transparent to kind/RTT introspection.
        assert_eq!(b.kind(), FarBackendKind::Pooled);
        assert_eq!(b.min_round_trip(), build_raw(&c, 3.0, 7).min_round_trip());
        // A same-cycle flood gets paced at the stream's 100% bandwidth
        // share; the admission delay surfaces as pool_steal_cycles.
        for i in 0..32u64 {
            b.read(0, i * 4096, 64);
            b.complete();
        }
        assert!(b.scenario_stats().get(ScenarioCol::PoolStealCycles) > 0);
        assert_eq!(b.scenario_stats().get(ScenarioCol::TenantSlowdownMax), 0);
    }

    #[test]
    fn fair_share_favors_the_heavier_weight() {
        let c = qos_cfg(QosPolicyKind::FairShare);
        let mut sh = shares(2);
        sh[0].weight = 3;
        let shared = SharedFar::new(&c, 3.0, 5, sh);
        let mut heavy = SharedFar::handle(&shared, 0);
        let mut light = SharedFar::handle(&shared, 1);
        let (mut last_heavy, mut last_light) = (0, 0);
        for i in 0..64u64 {
            last_heavy = heavy.read(0, i * 4096, 64).done;
            heavy.complete();
            last_light = light.read(0, (i + 1000) * 4096, 64).done;
            light.complete();
        }
        assert!(
            last_heavy < last_light,
            "weight 3 ({last_heavy}) must outrun weight 1 ({last_light})"
        );
        assert!(shared.lock().unwrap().steal_cycles() > 0, "a flood must be paced");
    }

    #[test]
    fn priority_gates_low_class_behind_the_high_class_backlog() {
        let c = qos_cfg(QosPolicyKind::Priority);
        let mut sh = shares(2);
        sh[0].class = QosClass::High;
        sh[1].class = QosClass::Low;
        let shared = SharedFar::new(&c, 3.0, 5, sh.clone());
        let mut high = SharedFar::handle(&shared, 0);
        let mut low = SharedFar::handle(&shared, 1);
        for i in 0..32u64 {
            high.read(0, i * 4096, 64);
            high.complete();
        }
        assert_eq!(shared.lock().unwrap().steal_cycles(), 0, "high class is never gated");
        low.read(0, 1_000_000, 64);
        low.complete();
        assert!(
            shared.lock().unwrap().steal_cycles() > 0,
            "low class must wait out the high backlog"
        );

        // Symmetric check: a low-class flood never gates high admission.
        let shared2 = SharedFar::new(&c, 3.0, 5, sh);
        let mut high2 = SharedFar::handle(&shared2, 0);
        let mut low2 = SharedFar::handle(&shared2, 1);
        for i in 0..32u64 {
            low2.read(0, i * 4096, 64);
            low2.complete();
        }
        high2.read(0, 1_000_000, 64);
        high2.complete();
        assert_eq!(shared2.lock().unwrap().steal_cycles(), 0, "low traffic cannot gate high");
    }

    #[test]
    fn throttle_rate_limits_a_congesting_tenant() {
        let mut c = qos_cfg(QosPolicyKind::Throttle);
        c.pool_channels = 1;
        c.pool_queue_depth = 1;
        c.pool_adapt_threshold = 0.5;
        c.pool_adapt_window = 8;
        let shared = SharedFar::new(&c, 3.0, 1, shares(1));
        let mut h = SharedFar::handle(&shared, 0);
        for _ in 0..64 {
            h.read(0, 0, 64);
            h.complete();
        }
        assert!(shared.lock().unwrap().is_throttled(0));
        let s = h.scenario_stats();
        assert!(
            s.get(ScenarioCol::QosThrottleEvents) > 0,
            "sustained congestion must trip the throttle"
        );
        assert!(s.get(ScenarioCol::PoolStealCycles) > 0, "throttled requests must be spaced");
        assert!(s.get(ScenarioCol::PoolCongestion) > 0, "the inner counters still flow through");

        // An uncongested stream is never throttled: timing identical to
        // the bare pool (throttle degenerates to a passthrough).
        let c2 = qos_cfg(QosPolicyKind::Throttle);
        let shared2 = SharedFar::new(&c2, 3.0, 1, shares(1));
        let mut calm = SharedFar::handle(&shared2, 0);
        let mut raw = build_raw(&c2, 3.0, 1);
        for i in 0..64u64 {
            let a = calm.read(i * 20_000, i * 4096, 64).done;
            calm.complete();
            let b = raw.read(i * 20_000, i * 4096, 64).done;
            raw.complete();
            assert_eq!(a, b, "uncongested throttle must be a passthrough");
        }
        assert_eq!(shared2.lock().unwrap().throttle_events(), 0);
    }

    #[test]
    fn shared_handles_track_inflight_per_tenant() {
        let c = qos_cfg(QosPolicyKind::FairShare);
        let shared = SharedFar::new(&c, 3.0, 3, shares(2));
        let mut a = SharedFar::handle(&shared, 0);
        let mut b = SharedFar::handle(&shared, 1);
        for i in 0..3u64 {
            a.read(0, i * 4096, 64);
        }
        b.read(0, 0, 64);
        assert_eq!(a.inflight(), 3);
        assert_eq!(b.inflight(), 1, "tenant MLP accounting must not leak across handles");
        a.complete();
        a.complete();
        assert_eq!(a.inflight(), 1);
        assert_eq!(b.inflight(), 1);
    }

    #[test]
    fn shared_backend_is_deterministic_for_identical_streams() {
        for &policy in QosPolicyKind::ALL {
            let mut c = qos_cfg(policy);
            c.jitter_frac = 0.05;
            c.pool_queue_depth = 2;
            let mk = || {
                let mut sh = shares(2);
                sh[0].weight = 2;
                sh[1].class = QosClass::Low;
                SharedFar::new(&c, 3.0, 11, sh)
            };
            let s1 = mk();
            let s2 = mk();
            let (mut a0, mut a1) = (SharedFar::handle(&s1, 0), SharedFar::handle(&s1, 1));
            let (mut b0, mut b1) = (SharedFar::handle(&s2, 0), SharedFar::handle(&s2, 1));
            for i in 0..200u64 {
                let addr = if i % 2 == 0 { 0 } else { i * 4096 };
                assert_eq!(
                    a0.read(i * 50, addr, 64).done,
                    b0.read(i * 50, addr, 64).done,
                    "{policy:?} tenant 0"
                );
                a0.complete();
                b0.complete();
                assert_eq!(
                    a1.read(i * 50 + 7, addr ^ 64, 64).done,
                    b1.read(i * 50 + 7, addr ^ 64, 64).done,
                    "{policy:?} tenant 1"
                );
                a1.complete();
                b1.complete();
            }
            assert_eq!(
                s1.lock().unwrap().scenario_snapshot(),
                s2.lock().unwrap().scenario_snapshot(),
                "{policy:?} counters"
            );
        }
    }
}
