//! Hardware prefetchers.
//!
//! The paper's `CXL Ideal` configuration carries an L2 **best-offset (BOP)**
//! prefetcher [Michaud, HPCA'16]. We implement the core BOP learning loop:
//! a recent-requests (RR) table remembers recent fill base addresses; a
//! round-robin scoring phase tests candidate offsets against the RR table;
//! the best-scoring offset becomes the active prefetch offset. A simple
//! stride prefetcher is also provided for ablations.

use super::cache::{line_of, LINE_BYTES};

/// Candidate offsets from the BOP paper (multiples with small factors).
const OFFSETS: &[i64] = &[
    1, 2, 3, 4, 5, 6, 8, 9, 10, 12, 15, 16, 18, 20, 24, 25, 27, 30, 32, 36, 40, 45, 48, 50,
];
const SCORE_MAX: u32 = 31;
const BAD_SCORE: u32 = 1;
const ROUND_MAX: u32 = 100;
const RR_ENTRIES: usize = 64;

pub struct BestOffset {
    rr: [u64; RR_ENTRIES],
    scores: Vec<u32>,
    test_idx: usize,
    round: u32,
    /// Currently active offset in lines (0 = prefetch off).
    pub active_offset: i64,
    pub issued: u64,
}

impl Default for BestOffset {
    fn default() -> Self {
        Self::new()
    }
}

impl BestOffset {
    pub fn new() -> Self {
        Self {
            rr: [u64::MAX; RR_ENTRIES],
            scores: vec![0; OFFSETS.len()],
            test_idx: 0,
            round: 0,
            active_offset: 1,
            issued: 0,
        }
    }

    #[inline]
    fn rr_index(line: u64) -> usize {
        ((line / LINE_BYTES) as usize) % RR_ENTRIES
    }

    /// Record a completed fill's *base* address (X - D for the active D, so
    /// learning measures timeliness, per the BOP paper; we use X directly —
    /// the standard simplification when fills are not tagged).
    pub fn on_fill(&mut self, addr: u64) {
        let line = line_of(addr);
        self.rr[Self::rr_index(line)] = line;
    }

    /// Called on every demand access at L2; returns a line address to
    /// prefetch, if the active offset is trained.
    pub fn on_demand(&mut self, addr: u64) -> Option<u64> {
        let line = line_of(addr);
        // Learning: test one offset per access.
        let d = OFFSETS[self.test_idx];
        let base = line.wrapping_sub((d * LINE_BYTES as i64) as u64);
        if self.rr[Self::rr_index(base)] == base {
            self.scores[self.test_idx] += 1;
        }
        self.test_idx += 1;
        if self.test_idx == OFFSETS.len() {
            self.test_idx = 0;
            self.round += 1;
            let (best_i, &best_s) = self
                .scores
                .iter()
                .enumerate()
                .max_by_key(|(_, &s)| s)
                .unwrap();
            if best_s >= SCORE_MAX || self.round >= ROUND_MAX {
                self.active_offset = if best_s > BAD_SCORE { OFFSETS[best_i] } else { 0 };
                self.scores.iter_mut().for_each(|s| *s = 0);
                self.round = 0;
            }
        }
        if self.active_offset != 0 {
            self.issued += 1;
            Some(line.wrapping_add((self.active_offset * LINE_BYTES as i64) as u64))
        } else {
            None
        }
    }
}

/// Per-PC stride prefetcher (ablation alternative to BOP).
pub struct StridePf {
    table: Vec<StrideEntry>,
}

#[derive(Clone, Copy, Default)]
struct StrideEntry {
    tag: u64,
    last_addr: u64,
    stride: i64,
    confidence: u8,
}

impl StridePf {
    pub fn new(entries: usize) -> Self {
        Self { table: vec![StrideEntry::default(); entries] }
    }

    pub fn on_access(&mut self, pc: u64, addr: u64) -> Option<u64> {
        let idx = (pc as usize) % self.table.len();
        let e = &mut self.table[idx];
        if e.tag != pc {
            *e = StrideEntry { tag: pc, last_addr: addr, stride: 0, confidence: 0 };
            return None;
        }
        let new_stride = addr as i64 - e.last_addr as i64;
        if new_stride == e.stride && new_stride != 0 {
            e.confidence = (e.confidence + 1).min(3);
        } else {
            e.confidence = e.confidence.saturating_sub(1);
            e.stride = new_stride;
        }
        e.last_addr = addr;
        if e.confidence >= 2 {
            Some(line_of((addr as i64 + 2 * e.stride) as u64))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bop_learns_sequential_stream() {
        let mut b = BestOffset::new();
        // Sequential line stream: offset 1 should stay/become active and
        // prefetches should be emitted for line+offset.
        let mut prefetched = Vec::new();
        for i in 0..2000u64 {
            let addr = i * LINE_BYTES;
            b.on_fill(addr);
            if let Some(p) = b.on_demand(addr) {
                prefetched.push(p);
            }
        }
        assert!(!prefetched.is_empty());
        assert!(b.active_offset >= 1);
        // Active offset must map demand X to X + D*64.
        let d = b.active_offset as u64;
        let last_demand = 1999 * LINE_BYTES;
        assert_eq!(*prefetched.last().unwrap(), last_demand + d * LINE_BYTES);
    }

    #[test]
    fn bop_learns_strided_stream() {
        let mut b = BestOffset::new();
        for i in 0..4000u64 {
            let addr = i * 4 * LINE_BYTES; // stride of 4 lines
            b.on_fill(addr);
            b.on_demand(addr);
        }
        assert_eq!(b.active_offset % 4, 0, "offset {} should be a multiple of 4", b.active_offset);
    }

    #[test]
    fn bop_disables_on_random_stream() {
        let mut b = BestOffset::new();
        let mut rng = crate::util::prng::Xoshiro256::new(3);
        for _ in 0..50_000 {
            let addr = rng.below(1 << 30) & !(LINE_BYTES - 1);
            b.on_fill(addr);
            b.on_demand(addr);
        }
        // On random traffic no offset scores well: prefetching turns off.
        assert_eq!(b.active_offset, 0, "random stream must disable BOP");
    }

    #[test]
    fn stride_pf_detects_constant_stride() {
        let mut s = StridePf::new(64);
        let pc = 0x400;
        let mut out = None;
        for i in 0..8u64 {
            out = s.on_access(pc, 0x1000 + i * 256);
        }
        let p = out.expect("stride detected");
        assert_eq!(p, line_of(0x1000 + 7 * 256 + 2 * 256));
    }

    #[test]
    fn stride_pf_ignores_random() {
        let mut s = StridePf::new(64);
        let mut rng = crate::util::prng::Xoshiro256::new(5);
        let mut fired = 0;
        for _ in 0..1000 {
            if s.on_access(0x400, rng.next_u64() & 0xFFFFF).is_some() {
                fired += 1;
            }
        }
        assert!(fired < 50, "random stream fired {fired} prefetches");
    }
}
