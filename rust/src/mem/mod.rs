//! Memory system: L1D + L2 caches with MSHRs, local DRAM, a pluggable
//! far-memory backend (serial link by default — see [`backend`]),
//! prefetching, and the SPM carve-out — glued together with a
//! deterministic event queue and driven by the cycle-stepped core.
//!
//! Demand path: core -> L1D -> L2 -> {DRAM | far backend}. AMU path: the
//! ASMC issues far requests directly onto the backend (data lands in the
//! SPM, not the caches), which is why AMI requests consume no cache MSHRs
//! — the paper's key resource argument.

pub mod backend;
pub mod cache;
pub mod dram;
pub mod link;
pub mod prefetch;

use crate::config::SimConfig;
use crate::isa::mem::{region_of, MemRegion};
use backend::FarBackend;
use cache::{line_of, Cache, LookupResult, Target};
use dram::Dram;
use prefetch::BestOffset;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    Load,
    Store,
    Prefetch,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitResult {
    Accepted,
    MshrFull,
    PortBusy,
}

#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub token: u32,
    pub cycle: u64,
    pub was_store: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// L1 miss request reaches L2.
    L2Req { line: u64, to_l1: bool, is_store: bool },
    /// Retry an L2 request that found the MSHR file full.
    L2Fill { line: u64 },
    L1Fill { line: u64 },
    /// Deliver a demand completion to the core.
    Done { token: u32, is_store: bool },
    /// ASMC far request finished (sub-request granularity).
    AsmcDone { token: u32 },
}

pub struct MemSys {
    pub l1d: Cache,
    pub l2: Cache,
    pub dram: Dram,
    /// The far-memory data plane selected by `cfg.far.backend`.
    pub link: Box<dyn FarBackend>,
    bop: Option<BestOffset>,
    pf_quota: usize,
    events: BinaryHeap<Reverse<(u64, u64, Ev)>>,
    seq: u64,
    /// Demand completions for the core, drained every cycle.
    pub completions: Vec<Completion>,
    /// Far-request completions for the ASMC.
    pub asmc_completions: Vec<Completion>,
    // L1 port accounting.
    ports: usize,
    ports_used: usize,
    port_cycle: u64,
    pub mshr_rejects: u64,
    pub pf_issued: u64,
}

impl MemSys {
    pub fn new(cfg: &SimConfig) -> Self {
        let bop = if cfg.prefetch.l2_best_offset {
            Some(BestOffset::new())
        } else {
            None
        };
        let pf_quota =
            ((cfg.l2.mshrs as f64) * cfg.prefetch.mshr_quota.clamp(0.0, 1.0)) as usize;
        Self {
            l1d: Cache::new(&cfg.l1d, "L1D"),
            l2: Cache::new(&cfg.l2, "L2"),
            dram: Dram::new(&cfg.dram, cfg.core.freq_ghz),
            link: backend::build(&cfg.far, cfg.core.freq_ghz, cfg.seed),
            bop,
            pf_quota,
            events: BinaryHeap::new(),
            seq: 0,
            completions: Vec::new(),
            asmc_completions: Vec::new(),
            ports: cfg.l1d.ports,
            ports_used: 0,
            port_cycle: 0,
            mshr_rejects: 0,
            pf_issued: 0,
        }
    }

    #[inline]
    fn schedule(&mut self, at: u64, ev: Ev) {
        self.seq += 1;
        self.events.push(Reverse((at, self.seq, ev)));
    }

    /// Demand access from the core (L1D). `token` is returned on completion.
    pub fn submit(
        &mut self,
        kind: AccessKind,
        addr: u64,
        token: u32,
        now: u64,
        l1_hit_lat: u64,
    ) -> SubmitResult {
        // Port accounting per cycle.
        if self.port_cycle != now {
            self.port_cycle = now;
            self.ports_used = 0;
        }
        if self.ports_used >= self.ports {
            return SubmitResult::PortBusy;
        }
        let line = line_of(addr);
        let is_store = kind == AccessKind::Store;
        match self.l1d.access(line, is_store) {
            LookupResult::Hit => {
                self.ports_used += 1;
                if kind != AccessKind::Prefetch {
                    self.schedule(now + l1_hit_lat, Ev::Done { token, is_store });
                }
                SubmitResult::Accepted
            }
            LookupResult::Miss => {
                let target = match kind {
                    AccessKind::Prefetch => Target::Prefetch,
                    _ => Target::Core { token, is_store },
                };
                if self.l1d.mshr_find(line).is_some() {
                    // Secondary miss: merge.
                    if self.l1d.mshr_add_target(line, target) {
                        self.ports_used += 1;
                        SubmitResult::Accepted
                    } else {
                        self.mshr_rejects += 1;
                        SubmitResult::MshrFull
                    }
                } else {
                    let is_far = region_of(addr) == MemRegion::Far;
                    if self.l1d.mshr_alloc(line, target, is_far, now) {
                        self.ports_used += 1;
                        self.schedule(
                            now + l1_hit_lat,
                            Ev::L2Req { line, to_l1: true, is_store },
                        );
                        SubmitResult::Accepted
                    } else {
                        self.mshr_rejects += 1;
                        SubmitResult::MshrFull
                    }
                }
            }
        }
    }

    /// ASMC far read/write of `bytes` at `addr`; completion shows up in
    /// `asmc_completions` with `token`. Bypasses the caches entirely.
    pub fn far_direct(&mut self, is_write: bool, addr: u64, bytes: usize, token: u32, now: u64) {
        let t = if is_write {
            self.link.write(now, addr, bytes)
        } else {
            self.link.read(now, addr, bytes)
        };
        self.schedule(t.done, Ev::AsmcDone { token });
    }

    /// Flush one line out of L1D+L2 (sync/async region transition).
    pub fn flush_line(&mut self, addr: u64, now: u64) {
        let line = line_of(addr);
        if self.l1d.invalidate(line) == Some(true) {
            // Dirty in L1: push down to L2 (install as dirty if present).
            if !self.l2.mark_dirty(line) {
                self.writeback_to_memory(line, now);
            }
        }
        if self.l2.invalidate(line) == Some(true) {
            self.writeback_to_memory(line, now);
        }
    }

    fn writeback_to_memory(&mut self, line: u64, now: u64) {
        match region_of(line) {
            MemRegion::Far => self.link.posted_write(now, line, 64),
            _ => {
                self.dram.service(now, line, true);
            }
        }
    }

    fn route_l2_miss(&mut self, line: u64, now: u64) -> u64 {
        match region_of(line) {
            MemRegion::Far => self.link.read(now, line, 64).done,
            _ => self.dram.service(now, line, false),
        }
    }

    /// Try to issue a hardware prefetch of `line` into L2.
    fn issue_l2_prefetch(&mut self, line: u64, now: u64, l2_lat: u64) {
        if self.l2.probe(line) || self.l2.mshr_find(line).is_some() {
            return;
        }
        if self.l2.mshr_prefetch_used() >= self.pf_quota || self.l2.mshr_full() {
            return;
        }
        let is_far = region_of(line) == MemRegion::Far;
        if self.l2.mshr_alloc(line, Target::Prefetch, is_far, now) {
            self.pf_issued += 1;
            let done = self.route_l2_miss(line, now + l2_lat);
            self.schedule(done, Ev::L2Fill { line });
        }
    }

    /// Advance to `now`: process all events due at or before `now`.
    /// Completions appear in `self.completions` / `self.asmc_completions`.
    pub fn tick(&mut self, now: u64, l2_hit_lat: u64, l2_to_l1: u64) {
        while let Some(Reverse((at, _, _))) = self.events.peek() {
            if *at > now {
                break;
            }
            let Reverse((at, _, ev)) = self.events.pop().unwrap();
            match ev {
                Ev::L2Req { line, to_l1, is_store } => {
                    // BOP observes demand traffic at L2.
                    if let Some(bop) = self.bop.as_mut() {
                        if let Some(pf_line) = bop.on_demand(line) {
                            if region_of(pf_line) == region_of(line) {
                                self.issue_l2_prefetch(pf_line, at, l2_hit_lat);
                            }
                        }
                    }
                    match self.l2.access(line, false) {
                        LookupResult::Hit => {
                            if to_l1 {
                                self.schedule(at + l2_hit_lat + l2_to_l1, Ev::L1Fill { line });
                            }
                        }
                        LookupResult::Miss => {
                            let target = if to_l1 { Target::FillL1 } else { Target::Prefetch };
                            if self.l2.mshr_find(line).is_some() {
                                if !self.l2.mshr_add_target(line, target) {
                                    // Target list full: retry shortly.
                                    self.schedule(
                                        at + 2,
                                        Ev::L2Req { line, to_l1, is_store },
                                    );
                                }
                            } else if self.l2.mshr_alloc(
                                line,
                                target,
                                region_of(line) == MemRegion::Far,
                                at,
                            ) {
                                let done = self.route_l2_miss(line, at + l2_hit_lat);
                                self.schedule(done, Ev::L2Fill { line });
                            } else {
                                // L2 MSHRs exhausted: retry. The L1 MSHR
                                // stays occupied — back-pressure propagates.
                                self.mshr_rejects += 1;
                                self.schedule(at + 2, Ev::L2Req { line, to_l1, is_store });
                            }
                        }
                    }
                }
                Ev::L2Fill { line } => {
                    let mshr = self.l2.mshr_take(line).expect("L2 fill without MSHR");
                    if mshr.is_far {
                        self.link.complete();
                    }
                    if let Some(bop) = self.bop.as_mut() {
                        bop.on_fill(line);
                    }
                    let prefetched =
                        mshr.targets.iter().all(|t| matches!(t, Target::Prefetch));
                    if let Some(v) = self.l2.install(line, false, prefetched) {
                        if v.dirty {
                            self.writeback_to_memory(v.line, at);
                        }
                    }
                    if mshr.targets.iter().any(|t| matches!(t, Target::FillL1)) {
                        self.schedule(at + l2_to_l1, Ev::L1Fill { line });
                    }
                }
                Ev::L1Fill { line } => {
                    let mshr = self.l1d.mshr_take(line).expect("L1 fill without MSHR");
                    let any_store = mshr
                        .targets
                        .iter()
                        .any(|t| matches!(t, Target::Core { is_store: true, .. }));
                    let all_pf = mshr.targets.iter().all(|t| matches!(t, Target::Prefetch));
                    if let Some(v) = self.l1d.install(line, any_store, all_pf) {
                        if v.dirty {
                            // Write back into L2; if absent there, straight
                            // to memory (no-allocate on writeback).
                            if !self.l2.mark_dirty(v.line) {
                                self.writeback_to_memory(v.line, at);
                            }
                        }
                    }
                    for t in mshr.targets {
                        if let Target::Core { token, is_store } = t {
                            self.schedule(at + 1, Ev::Done { token, is_store });
                        }
                    }
                }
                Ev::Done { token, is_store } => {
                    self.completions.push(Completion { token, cycle: at, was_store: is_store });
                }
                Ev::AsmcDone { token } => {
                    self.link.complete();
                    self.asmc_completions
                        .push(Completion { token, cycle: at, was_store: false });
                }
            }
        }
    }

    /// Far requests currently in flight (demand + AMU) — the Fig 9 metric.
    pub fn far_inflight(&self) -> u64 {
        self.link.inflight()
    }

    /// Backend scenario counters (near-tier hits/evictions, pool channel
    /// congestion), harvested into `Stats` at the end of a run.
    pub fn scenario_stats(&self) -> backend::ScenarioStats {
        self.link.scenario_stats()
    }

    pub fn pending_events(&self) -> usize {
        self.events.len()
    }

    /// Earliest future cycle at which the memory system can change state on
    /// its own: the head of the event queue, or any backend-internal timer
    /// (see [`FarBackend::next_event_cycle`]). `None` means fully idle —
    /// nothing will happen until the core submits new work.
    pub fn next_event_cycle(&self, now: u64) -> Option<u64> {
        let ev = self.events.peek().map(|Reverse((at, _, _))| *at);
        match (ev, self.link.next_event_cycle(now)) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    // ---- fast-forward support ----

    /// Mix everything an idle-retry tick could *structurally* change into a
    /// state fingerprint. `(events.len, seq)` captures any schedule or pop
    /// (`seq` is monotone), completion queue lengths capture undrained
    /// deliveries, and the MSHR files capture miss-tracking state. Counters
    /// (`mshr_rejects`, cache access tallies) are deliberately excluded —
    /// they may advance every retry tick and are folded in closed form via
    /// [`MemSys::counter_snapshot`] / [`MemSys::fold_idle_counters`].
    pub fn state_signature(&self, h: &mut crate::util::Mix64) {
        h.mix(self.events.len() as u64);
        h.mix(self.seq);
        h.mix(self.completions.len() as u64);
        h.mix(self.asmc_completions.len() as u64);
        h.mix(self.link.inflight());
        self.l1d.mshr_signature(h);
        self.l2.mshr_signature(h);
    }

    /// Snapshot the counters a rejected-access retry tick can advance.
    pub fn counter_snapshot(&self) -> MemCounterSnap {
        MemCounterSnap {
            mshr_rejects: self.mshr_rejects,
            pf_issued: self.pf_issued,
            l1d: self.l1d.counter_snapshot(),
            l2: self.l2.counter_snapshot(),
        }
    }

    /// Replicate one idle tick's counter deltas across `k` skipped ticks.
    pub fn fold_idle_counters(&mut self, k: u64, before: &MemCounterSnap) {
        self.mshr_rejects += k * (self.mshr_rejects - before.mshr_rejects);
        self.pf_issued += k * (self.pf_issued - before.pf_issued);
        self.l1d.fold_counters(k, &before.l1d);
        self.l2.fold_counters(k, &before.l2);
    }
}

/// Snapshot of the memory-system counters an idle pipeline tick can move
/// (see [`MemSys::counter_snapshot`]).
pub struct MemCounterSnap {
    mshr_rejects: u64,
    pf_issued: u64,
    l1d: [u64; 5],
    l2: [u64; 5],
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::isa::mem::{FAR_BASE, LOCAL_BASE};

    fn memsys(cfg: &SimConfig) -> MemSys {
        MemSys::new(cfg)
    }

    fn drain_until(m: &mut MemSys, token: u32, max: u64) -> u64 {
        for c in 0..max {
            m.tick(c, 10, 4);
            if let Some(done) = m.completions.iter().find(|x| x.token == token) {
                return done.cycle;
            }
        }
        panic!("token {token} never completed");
    }

    #[test]
    fn l1_hit_completes_fast() {
        let cfg = SimConfig::baseline();
        let mut m = memsys(&cfg);
        // Prime the line.
        assert_eq!(
            m.submit(AccessKind::Load, LOCAL_BASE, 1, 0, 4),
            SubmitResult::Accepted
        );
        let t1 = drain_until(&mut m, 1, 100_000);
        // Second access: hit.
        assert_eq!(
            m.submit(AccessKind::Load, LOCAL_BASE, 2, t1 + 1, 4),
            SubmitResult::Accepted
        );
        let t2 = drain_until(&mut m, 2, t1 + 100);
        assert_eq!(t2 - (t1 + 1), 4, "L1 hit latency");
    }

    #[test]
    fn local_miss_latency_reasonable() {
        let cfg = SimConfig::baseline();
        let mut m = memsys(&cfg);
        m.submit(AccessKind::Load, LOCAL_BASE + 1 << 20, 1, 0, 4);
        let t = drain_until(&mut m, 1, 100_000);
        // L1 lat + L2 lat + DRAM row miss (135c) + xfer (10) + fill hops.
        assert!(t > 100 && t < 400, "local miss latency {t}");
    }

    #[test]
    fn far_miss_latency_includes_link() {
        let cfg = SimConfig::baseline().with_far_latency_ns(1000.0);
        let mut m = memsys(&cfg);
        m.submit(AccessKind::Load, FAR_BASE, 1, 0, 4);
        let t = drain_until(&mut m, 1, 1_000_000);
        assert!(t >= 3000, "far miss must include 3000-cycle link RTT, got {t}");
        assert!(t < 4500, "far miss too slow: {t}");
    }

    #[test]
    fn far_path_respects_selected_backend() {
        use crate::config::FarBackendKind;
        for &k in FarBackendKind::ALL {
            let mut cfg =
                SimConfig::baseline().with_far_latency_ns(1000.0).with_far_backend(k);
            cfg.far.jitter_frac = 0.0;
            let mut m = memsys(&cfg);
            assert_eq!(m.link.kind(), k);
            m.submit(AccessKind::Load, FAR_BASE, 1, 0, 4);
            let t = drain_until(&mut m, 1, 2_000_000);
            assert!(t > 100, "{k:?}: far miss implausibly fast: {t}");
            assert_eq!(m.far_inflight(), 0, "{k:?}: inflight accounting leaked");
        }
    }

    #[test]
    fn scenario_stats_surface_through_memsys() {
        use crate::config::FarBackendKind;
        let mut cfg = SimConfig::baseline()
            .with_far_latency_ns(1000.0)
            .with_far_backend(FarBackendKind::Hybrid);
        cfg.far.jitter_frac = 0.0;
        cfg.far.near_capacity_lines = 2;
        let mut m = memsys(&cfg);
        // Lines 0, 1, 0 again (hit), then a third line (evicts line 1).
        for (i, off) in [0u64, 64, 0, 128].iter().enumerate() {
            m.far_direct(false, FAR_BASE + off, 8, i as u32, (i as u64) * 20_000);
        }
        for c in 0..1_000_000 {
            m.tick(c, 10, 4);
            if m.asmc_completions.len() == 4 {
                break;
            }
        }
        let s = m.scenario_stats();
        use crate::stats::schema::ScenarioCol;
        assert_eq!(s.get(ScenarioCol::NearHits), 1, "third access re-touches line 0");
        assert_eq!(
            s.get(ScenarioCol::NearEvictions),
            1,
            "fourth access overflows the 2-line tier"
        );
    }

    #[test]
    fn mshr_exhaustion_rejects() {
        let mut cfg = SimConfig::baseline();
        cfg.l1d.mshrs = 2;
        let mut m = memsys(&cfg);
        assert_eq!(
            m.submit(AccessKind::Load, FAR_BASE, 1, 0, 4),
            SubmitResult::Accepted
        );
        assert_eq!(
            m.submit(AccessKind::Load, FAR_BASE + 4096, 2, 1, 4),
            SubmitResult::Accepted
        );
        assert_eq!(
            m.submit(AccessKind::Load, FAR_BASE + 8192, 3, 2, 4),
            SubmitResult::MshrFull
        );
        assert_eq!(m.mshr_rejects, 1);
    }

    #[test]
    fn secondary_miss_merges_same_line() {
        let mut cfg = SimConfig::baseline();
        cfg.l1d.mshrs = 1;
        let mut m = memsys(&cfg);
        assert_eq!(
            m.submit(AccessKind::Load, FAR_BASE, 1, 0, 4),
            SubmitResult::Accepted
        );
        // Same line: merge into existing MSHR even though the file is full.
        assert_eq!(
            m.submit(AccessKind::Load, FAR_BASE + 8, 2, 1, 4),
            SubmitResult::Accepted
        );
        let t1 = drain_until(&mut m, 1, 1_000_000);
        // Both complete off one fill.
        assert!(m.completions.iter().any(|c| c.token == 2));
        assert!(t1 >= 3000);
    }

    #[test]
    fn port_limit_per_cycle() {
        let cfg = SimConfig::baseline(); // 2 ports
        let mut m = memsys(&cfg);
        assert_eq!(m.submit(AccessKind::Load, LOCAL_BASE, 1, 5, 4), SubmitResult::Accepted);
        assert_eq!(
            m.submit(AccessKind::Load, LOCAL_BASE + 64, 2, 5, 4),
            SubmitResult::Accepted
        );
        assert_eq!(
            m.submit(AccessKind::Load, LOCAL_BASE + 128, 3, 5, 4),
            SubmitResult::PortBusy
        );
        // Next cycle the port frees up.
        assert_eq!(
            m.submit(AccessKind::Load, LOCAL_BASE + 128, 3, 6, 4),
            SubmitResult::Accepted
        );
    }

    #[test]
    fn store_miss_write_allocates_and_dirties() {
        let cfg = SimConfig::baseline();
        let mut m = memsys(&cfg);
        m.submit(AccessKind::Store, LOCAL_BASE + 4096, 1, 0, 4);
        let t = drain_until(&mut m, 1, 100_000);
        assert!(m.completions[0].was_store);
        // Line now present and dirty: flushing writes it back.
        let wb_before = m.dram.writes;
        m.flush_line(LOCAL_BASE + 4096, t + 1);
        assert_eq!(m.dram.writes, wb_before + 1);
    }

    #[test]
    fn asmc_far_direct_bypasses_caches() {
        let cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        let mut m = memsys(&cfg);
        let l1_misses_before = m.l1d.misses;
        m.far_direct(false, FAR_BASE, 8, 7, 0);
        let mut done = 0;
        for c in 0..1_000_000 {
            m.tick(c, 10, 4);
            if let Some(x) = m.asmc_completions.first() {
                done = x.cycle;
                break;
            }
        }
        assert!(done >= 3000);
        assert_eq!(m.l1d.misses, l1_misses_before, "no cache involvement");
        assert_eq!(m.far_inflight(), 0);
    }

    #[test]
    fn far_inflight_tracks_outstanding() {
        let cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        let mut m = memsys(&cfg);
        for i in 0..10 {
            m.far_direct(false, FAR_BASE + i * 4096, 8, i as u32, 0);
        }
        assert_eq!(m.far_inflight(), 10);
        for c in 0..1_000_000 {
            m.tick(c, 10, 4);
            if m.asmc_completions.len() == 10 {
                break;
            }
        }
        assert_eq!(m.far_inflight(), 0);
    }

    #[test]
    fn bop_prefetches_timely_on_slow_sequential_stream() {
        // Local DRAM (~165-cycle miss) with 200-cycle demand spacing: a
        // 1-line offset prefetch has enough lead time to land before the
        // demand — prefetch hits must accrue.
        let cfg = SimConfig::cxl_ideal();
        let mut m = memsys(&cfg);
        for i in 0..2000u64 {
            let cycle = i * 200;
            let addr = LOCAL_BASE + (1 << 22) + i * 64;
            m.tick(cycle, 10, 4);
            assert_eq!(
                m.submit(AccessKind::Load, addr, i as u32, cycle, 4),
                SubmitResult::Accepted
            );
        }
        for c in 2000 * 200..2000 * 200 + 10_000 {
            m.tick(c, 10, 4);
        }
        assert!(m.pf_issued > 100, "BOP should train on a sequential stream: {}", m.pf_issued);
        assert!(m.l2.prefetch_hits > 50, "prefetches should be useful: {}", m.l2.prefetch_hits);
    }

    #[test]
    fn bop_prefetches_are_late_at_far_latency() {
        // The same stream at back-to-back pace against 1.5k-cycle far
        // latency: prefetches are issued but arrive late (merge with the
        // demand miss) — the paper's prefetch-timeliness problem.
        let mut cfg = SimConfig::cxl_ideal().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let mut m = memsys(&cfg);
        let mut cycle = 0u64;
        for i in 0..3000u64 {
            let addr = FAR_BASE + i * 64;
            loop {
                m.tick(cycle, 10, 4);
                match m.submit(AccessKind::Load, addr, i as u32, cycle, 4) {
                    SubmitResult::Accepted => break,
                    _ => cycle += 1,
                }
            }
            cycle += 2;
        }
        for c in cycle..cycle + 100_000 {
            m.tick(c, 10, 4);
        }
        assert!(m.pf_issued > 100, "BOP still issues: {}", m.pf_issued);
        let hit_rate = m.l2.prefetch_hits as f64 / m.pf_issued as f64;
        assert!(
            hit_rate < 0.5,
            "at far latency most prefetches should be late, hit rate {hit_rate}"
        );
    }
}
