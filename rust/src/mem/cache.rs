//! Set-associative write-back cache with MSHRs.
//!
//! Used for both L1D and L2. The MSHR file is the paper's central scarce
//! resource: a cache-missing access holds an MSHR for the full miss
//! latency, and MSHR exhaustion back-pressures the pipeline — exactly the
//! synchronous-semantics bottleneck AMI is designed to break.

use crate::config::CacheConfig;

pub const LINE_BYTES: u64 = 64;

#[inline]
pub fn line_of(addr: u64) -> u64 {
    addr & !(LINE_BYTES - 1)
}

#[derive(Debug, Clone, Copy, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    prefetched: bool,
    last_use: u64,
}

/// Who gets notified when a miss fills.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Core token (load or store) — `is_store` sets the line dirty on fill.
    Core { token: u32, is_store: bool },
    /// A lower-level cache waits for this fill (L2 MSHR -> L1 fill).
    FillL1,
    /// Hardware or software prefetch: nobody to notify.
    Prefetch,
}

#[derive(Debug, Clone)]
pub struct Mshr {
    pub line: u64,
    pub targets: Vec<Target>,
    /// Completion routed over the far link (for MLP accounting).
    pub is_far: bool,
    pub allocated_at: u64,
}

const MAX_TARGETS: usize = 16;

#[derive(Debug, Clone, Copy)]
pub struct Victim {
    pub line: u64,
    pub dirty: bool,
}

pub struct Cache {
    sets: usize,
    ways: usize,
    lines: Vec<Line>,
    mshrs: Vec<Option<Mshr>>,
    clock: u64,
    pub name: &'static str,
    // Stats.
    pub accesses: u64,
    pub misses: u64,
    pub prefetch_hits: u64,
    pub writebacks: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LookupResult {
    Hit,
    Miss,
}

impl Cache {
    pub fn new(cfg: &CacheConfig, name: &'static str) -> Self {
        let sets = cfg.sets();
        assert!(sets > 0, "{name}: zero sets");
        Self {
            sets,
            ways: cfg.ways,
            lines: vec![Line::default(); sets * cfg.ways],
            mshrs: vec![None; cfg.mshrs],
            clock: 0,
            name,
            accesses: 0,
            misses: 0,
            prefetch_hits: 0,
            writebacks: 0,
        }
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        ((line / LINE_BYTES) % self.sets as u64) as usize
    }

    fn slot_range(&self, set: usize) -> std::ops::Range<usize> {
        set * self.ways..(set + 1) * self.ways
    }

    /// Tag probe without state change.
    pub fn probe(&self, line: u64) -> bool {
        let set = self.set_of(line);
        self.lines[self.slot_range(set)]
            .iter()
            .any(|l| l.valid && l.tag == line)
    }

    /// Demand access: updates LRU and dirty bit; returns Hit/Miss.
    pub fn access(&mut self, line: u64, is_write: bool) -> LookupResult {
        debug_assert_eq!(line % LINE_BYTES, 0);
        self.clock += 1;
        self.accesses += 1;
        let set = self.set_of(line);
        let clock = self.clock;
        for l in &mut self.lines[set * self.ways..(set + 1) * self.ways] {
            if l.valid && l.tag == line {
                l.last_use = clock;
                if is_write {
                    l.dirty = true;
                }
                if l.prefetched {
                    l.prefetched = false;
                    self.prefetch_hits += 1;
                }
                return LookupResult::Hit;
            }
        }
        self.misses += 1;
        LookupResult::Miss
    }

    /// Install a filled line; returns the evicted victim, if any.
    pub fn install(&mut self, line: u64, dirty: bool, prefetched: bool) -> Option<Victim> {
        self.clock += 1;
        let set = self.set_of(line);
        let range = self.slot_range(set);
        // Already present (e.g. refill raced a writeback-install): update.
        let clock = self.clock;
        for l in &mut self.lines[range.clone()] {
            if l.valid && l.tag == line {
                l.dirty |= dirty;
                // Merge the prefetch flag: the line only stays credited to
                // the prefetcher if *both* fills were prefetches. A demand
                // install racing a prefetch fill used to leave the stale
                // flag set, inflating `prefetch_hits` on the next access.
                l.prefetched &= prefetched;
                l.last_use = clock;
                return None;
            }
        }
        // Choose an invalid way or the LRU way.
        let mut victim_idx = range.start;
        let mut best = u64::MAX;
        for i in range {
            let l = &self.lines[i];
            if !l.valid {
                victim_idx = i;
                break;
            }
            if l.last_use < best {
                best = l.last_use;
                victim_idx = i;
            }
        }
        let old = self.lines[victim_idx];
        self.lines[victim_idx] =
            Line { tag: line, valid: true, dirty, prefetched, last_use: self.clock };
        if old.valid {
            if old.dirty {
                self.writebacks += 1;
            }
            Some(Victim { line: old.tag, dirty: old.dirty })
        } else {
            None
        }
    }

    /// Invalidate `line`; returns whether it was present and dirty.
    pub fn invalidate(&mut self, line: u64) -> Option<bool> {
        let set = self.set_of(line);
        for l in &mut self.lines[set * self.ways..(set + 1) * self.ways] {
            if l.valid && l.tag == line {
                l.valid = false;
                let was_dirty = l.dirty;
                if was_dirty {
                    self.writebacks += 1;
                }
                return Some(was_dirty);
            }
        }
        None
    }

    /// Mark a present line dirty (store completing into an existing line).
    pub fn mark_dirty(&mut self, line: u64) -> bool {
        let set = self.set_of(line);
        for l in &mut self.lines[set * self.ways..(set + 1) * self.ways] {
            if l.valid && l.tag == line {
                l.dirty = true;
                return true;
            }
        }
        false
    }

    // ---- MSHR management ----

    pub fn mshr_find(&mut self, line: u64) -> Option<&mut Mshr> {
        self.mshrs
            .iter_mut()
            .filter_map(|m| m.as_mut())
            .find(|m| m.line == line)
    }

    /// Allocate an MSHR for `line` with one initial target.
    /// Returns false if the file is full (structural hazard).
    pub fn mshr_alloc(&mut self, line: u64, target: Target, is_far: bool, now: u64) -> bool {
        debug_assert!(self.mshr_find(line).is_none(), "{}: double alloc", self.name);
        for slot in self.mshrs.iter_mut() {
            if slot.is_none() {
                *slot = Some(Mshr { line, targets: vec![target], is_far, allocated_at: now });
                return true;
            }
        }
        false
    }

    /// Add a secondary-miss target; false if the target list is full.
    pub fn mshr_add_target(&mut self, line: u64, target: Target) -> bool {
        match self.mshr_find(line) {
            Some(m) if m.targets.len() < MAX_TARGETS => {
                m.targets.push(target);
                true
            }
            _ => false,
        }
    }

    /// Remove and return the MSHR for `line` (on fill).
    pub fn mshr_take(&mut self, line: u64) -> Option<Mshr> {
        for slot in self.mshrs.iter_mut() {
            if slot.as_ref().is_some_and(|m| m.line == line) {
                return slot.take();
            }
        }
        None
    }

    pub fn mshr_used(&self) -> usize {
        self.mshrs.iter().filter(|m| m.is_some()).count()
    }

    pub fn mshr_capacity(&self) -> usize {
        self.mshrs.len()
    }

    pub fn mshr_full(&self) -> bool {
        self.mshrs.iter().all(|m| m.is_some())
    }

    /// Number of MSHRs holding prefetch-only requests (quota enforcement).
    pub fn mshr_prefetch_used(&self) -> usize {
        self.mshrs
            .iter()
            .filter_map(|m| m.as_ref())
            .filter(|m| m.targets.iter().all(|t| *t == Target::Prefetch))
            .count()
    }

    // ---- fast-forward support ----

    /// Counters advanced by rejected (retrying) accesses: the LRU clock and
    /// the access/miss tallies. A rejected access never touches line state,
    /// so these are the only fields an idle pipeline tick can move — the
    /// simulator's fast-forward snapshots them, proves one tick is a fixed
    /// point, and replays the deltas in closed form via [`Cache::fold_counters`].
    pub fn counter_snapshot(&self) -> [u64; 5] {
        [self.clock, self.accesses, self.misses, self.prefetch_hits, self.writebacks]
    }

    /// Replicate one idle tick's counter deltas across `k` skipped ticks.
    /// Folding `clock` keeps future `last_use` stamps — and therefore LRU
    /// victim choice — identical to a tick-by-tick run.
    pub fn fold_counters(&mut self, k: u64, before: &[u64; 5]) {
        self.clock += k * (self.clock - before[0]);
        self.accesses += k * (self.accesses - before[1]);
        self.misses += k * (self.misses - before[2]);
        self.prefetch_hits += k * (self.prefetch_hits - before[3]);
        self.writebacks += k * (self.writebacks - before[4]);
    }

    /// Mix the MSHR file's occupancy identity into a state fingerprint.
    pub fn mshr_signature(&self, h: &mut crate::util::Mix64) {
        for slot in &self.mshrs {
            match slot {
                Some(m) => {
                    h.mix(m.line | 1);
                    h.mix(m.allocated_at);
                    h.mix((m.targets.len() as u64) << 1 | m.is_far as u64);
                }
                None => h.mix(0),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CacheConfig;

    fn small() -> Cache {
        Cache::new(
            &CacheConfig {
                size_bytes: 4 * 1024,
                ways: 4,
                line_bytes: 64,
                mshrs: 4,
                hit_latency: 4,
                ports: 2,
            },
            "test",
        )
    }

    #[test]
    fn miss_then_hit_after_install() {
        let mut c = small();
        assert_eq!(c.access(0x1000, false), LookupResult::Miss);
        assert!(c.install(0x1000, false, false).is_none());
        assert_eq!(c.access(0x1000, false), LookupResult::Hit);
        assert_eq!(c.misses, 1);
        assert_eq!(c.accesses, 2);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = small();
        // 4 ways; fill 5 lines in the same set (set stride = sets*64 = 16*64).
        let stride = 16 * 64u64;
        for i in 0..4 {
            c.install(i * stride, false, false);
        }
        // Touch line 0 to make it MRU.
        c.access(0, false);
        let v = c.install(4 * stride, false, false).expect("eviction");
        assert_eq!(v.line, stride, "LRU (line 1) should be evicted");
        assert!(c.probe(0));
        assert!(!c.probe(stride));
    }

    #[test]
    fn dirty_victim_reported() {
        let mut c = small();
        let stride = 16 * 64u64;
        c.install(0, false, false);
        c.access(0, true); // dirty it
        for i in 1..=4 {
            c.install(i * stride, false, false);
        }
        // line 0 eventually evicted dirty
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn invalidate_returns_dirtiness() {
        let mut c = small();
        c.install(0x40, true, false);
        assert_eq!(c.invalidate(0x40), Some(true));
        assert_eq!(c.invalidate(0x40), None);
        assert!(!c.probe(0x40));
    }

    #[test]
    fn mshr_alloc_and_exhaustion() {
        let mut c = small();
        for i in 0..4 {
            assert!(c.mshr_alloc(
                i * 64,
                Target::Core { token: i as u32, is_store: false },
                false,
                0
            ));
        }
        assert!(c.mshr_full());
        assert!(!c.mshr_alloc(0x9999 & !63, Target::Prefetch, false, 0));
        let m = c.mshr_take(0).unwrap();
        assert_eq!(m.targets.len(), 1);
        assert!(!c.mshr_full());
    }

    #[test]
    fn secondary_miss_merges() {
        let mut c = small();
        assert!(c.mshr_alloc(0x1000, Target::Core { token: 1, is_store: false }, true, 5));
        assert!(c.mshr_add_target(0x1000, Target::Core { token: 2, is_store: true }));
        let m = c.mshr_take(0x1000).unwrap();
        assert_eq!(m.targets.len(), 2);
        assert!(m.is_far);
        assert_eq!(m.allocated_at, 5);
        assert_eq!(c.mshr_used(), 0);
    }

    #[test]
    fn target_list_cap() {
        let mut c = small();
        c.mshr_alloc(0, Target::Prefetch, false, 0);
        for _ in 0..MAX_TARGETS - 1 {
            assert!(c.mshr_add_target(0, Target::Prefetch));
        }
        assert!(!c.mshr_add_target(0, Target::Prefetch), "cap at {MAX_TARGETS}");
    }

    #[test]
    fn prefetch_hit_accounting() {
        let mut c = small();
        c.install(0x80, false, true);
        assert_eq!(c.access(0x80, false), LookupResult::Hit);
        assert_eq!(c.prefetch_hits, 1);
        // Second hit doesn't double count.
        c.access(0x80, false);
        assert_eq!(c.prefetch_hits, 1);
        // A demand fill racing a prefetch install must clear the flag: the
        // demand brought the line, so the later hit is not a prefetch hit.
        c.install(0x2000, false, true); // prefetch fill
        c.install(0x2000, false, false); // racing demand install, same line
        c.access(0x2000, false);
        assert_eq!(c.prefetch_hits, 1, "demand-refilled line must not credit the prefetcher");
        // The reverse race: a prefetch fill landing on a demand-present
        // line must not mark it prefetched either.
        c.install(0x4000, false, false); // demand fill
        c.install(0x4000, false, true); // late prefetch fill, same line
        c.access(0x4000, false);
        assert_eq!(c.prefetch_hits, 1);
    }

    #[test]
    fn install_existing_line_merges_dirty() {
        let mut c = small();
        c.install(0x100 & !63, false, false);
        assert!(c.install(0x100 & !63, true, false).is_none());
        assert_eq!(c.invalidate(0x100 & !63), Some(true));
    }

    #[test]
    fn prefetch_mshr_quota_counting() {
        let mut c = small();
        c.mshr_alloc(0, Target::Prefetch, false, 0);
        c.mshr_alloc(64, Target::Core { token: 1, is_store: false }, false, 0);
        assert_eq!(c.mshr_prefetch_used(), 1);
    }
}
