//! DDR4-lite local DRAM timing model.
//!
//! Per-bank open-row tracking with row-hit/row-miss service times plus a
//! shared data-bus bandwidth constraint. Deliberately simpler than a full
//! DDR controller (no command scheduling / refresh), but it produces the
//! two behaviours the evaluation depends on: (1) random traffic pays the
//! row-miss penalty and (2) total throughput is capped by bus bandwidth.

use crate::config::DramConfig;

#[derive(Debug, Clone, Copy, Default)]
struct Bank {
    busy_until: u64,
    open_row: Option<u64>,
}

pub struct Dram {
    banks: Vec<Bank>,
    bus_free_at: u64,
    cfg: DramConfig,
    freq_ghz: f64,
    /// Cycles to move one 64 B line over the data bus.
    xfer_cycles: u64,
    row_hit_cycles: u64,
    row_miss_cycles: u64,
    pub reads: u64,
    pub writes: u64,
}

impl Dram {
    pub fn new(cfg: &DramConfig, freq_ghz: f64) -> Self {
        let xfer = (64.0 / cfg.bandwidth_gbps * freq_ghz).ceil() as u64;
        Self {
            banks: vec![Bank::default(); cfg.banks],
            bus_free_at: 0,
            xfer_cycles: xfer.max(1),
            row_hit_cycles: crate::util::ns_to_cycles(cfg.row_hit_ns, freq_ghz),
            row_miss_cycles: crate::util::ns_to_cycles(cfg.row_miss_ns, freq_ghz),
            cfg: cfg.clone(),
            freq_ghz,
            reads: 0,
            writes: 0,
        }
    }

    /// Service one 64 B line access starting no earlier than `cycle`;
    /// returns the absolute completion cycle.
    pub fn service(&mut self, cycle: u64, addr: u64, is_write: bool) -> u64 {
        if is_write {
            self.writes += 1;
        } else {
            self.reads += 1;
        }
        let row = addr / self.cfg.row_bytes as u64;
        let bank_idx = (row as usize) % self.banks.len();
        let bank = &mut self.banks[bank_idx];
        let start = cycle.max(bank.busy_until);
        let access = if bank.open_row == Some(row) {
            self.row_hit_cycles
        } else {
            bank.open_row = Some(row);
            self.row_miss_cycles
        };
        let data_ready = start + access;
        // Data bus: serialized transfers.
        let bus_start = data_ready.max(self.bus_free_at);
        let done = bus_start + self.xfer_cycles;
        self.bus_free_at = done;
        bank.busy_until = data_ready;
        done
    }

    pub fn peak_line_interval(&self) -> u64 {
        self.xfer_cycles
    }

    pub fn freq_ghz(&self) -> f64 {
        self.freq_ghz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dram() -> Dram {
        Dram::new(&DramConfig::default(), 3.0)
    }

    #[test]
    fn row_hit_faster_than_miss() {
        let mut d = dram();
        let t_miss = d.service(0, 0x1000, false); // first access: row miss
        let mut d2 = dram();
        d2.service(0, 0x1000, false);
        // Same row, after bank is free: row hit is cheaper.
        let start = t_miss + 100;
        let t_hit = d2.service(start, 0x1008, false) - start;
        assert!(t_hit < t_miss, "row hit {t_hit} should beat miss {t_miss}");
    }

    #[test]
    fn bank_serializes_same_bank() {
        let mut d = dram();
        let a = d.service(0, 0x0, false);
        let b = d.service(0, 0x0, false); // same row, same bank
        assert!(b > a);
    }

    #[test]
    fn different_banks_overlap() {
        let mut d = dram();
        let row_bytes = DramConfig::default().row_bytes as u64;
        let a = d.service(0, 0, false);
        let b = d.service(0, row_bytes, false); // next row -> different bank
        // Bank access overlaps; only the bus serializes, so b is close to a.
        assert!(b < a + d.peak_line_interval() + 2);
    }

    #[test]
    fn bus_bandwidth_caps_throughput() {
        let mut d = dram();
        let row_bytes = DramConfig::default().row_bytes as u64;
        let n = 64;
        let mut last = 0;
        for i in 0..n {
            // Spread across banks so only the bus constrains.
            last = d.service(0, i * row_bytes, false);
        }
        let min_cycles = (n - 8) * d.peak_line_interval();
        assert!(last >= min_cycles, "bus cap violated: {last} < {min_cycles}");
    }

    #[test]
    fn monotonic_completion() {
        let mut d = dram();
        let mut prev = 0;
        for i in 0..100u64 {
            let t = d.service(i * 2, i * 4096 + 0x100, i % 3 == 0);
            assert!(t >= prev || t >= i * 2);
            prev = t;
        }
        assert_eq!(d.reads + d.writes, 100);
    }
}
