"""Pure-jnp oracles for the Pallas payload kernels.

These are the correctness references (pytest asserts kernel == ref); they
also document the exact semantics the Rust integration tests mirror.
"""

import jax.numpy as jnp


def gups_update_ref(vals, idxs):
    """GUPS payload transform: new_val[i] = vals[i] ^ idxs[i]."""
    return vals ^ idxs


def stream_triad_ref(b, c, scalar):
    """STREAM triad: a = b + scalar * c."""
    return b + scalar * c


def spmv_ell_ref(vals, cols, x):
    """ELL SpMV row block: y[r] = sum_j vals[r, j] * x[cols[r, j]]."""
    gathered = x[cols]  # (rows, nnz)
    return jnp.sum(vals * gathered, axis=1)


def hash_mult_ref(keys):
    """Multiplicative hash used by the KV workloads (u32 splitmix round)."""
    h = (keys * jnp.uint32(0x9E3779B9)).astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = (h * jnp.uint32(0x85EBCA6B)).astype(jnp.uint32)
    return h ^ (h >> jnp.uint32(13))
