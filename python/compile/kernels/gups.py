"""Layer-1 Pallas kernels: the benchmark suite's payload transforms.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets a
RISC-V OoO core, not a GPU, so there is no threadblock structure to port.
What the far-memory tier actually *serves* in the evaluation are batched
payload transforms — GUPS xor-updates, STREAM triad blocks, ELL SpMV row
blocks, and multiplicative hashing. Each is expressed as a Pallas kernel
tiled for VMEM via `BlockSpec` (lane-multiple blocks), with the HBM<->VMEM
schedule carried by the grid. `interpret=True` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so numerics are validated
through the interpret path and TPU efficiency is estimated analytically
(EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane-friendly block sizes: multiples of 128 (TPU VPU lanes); 512 elements
# of 4 B = 2 KiB per operand block, far under the VMEM budget, which lets
# the compiler double-buffer the HBM streams.
BLOCK = 512


def _gups_kernel(vals_ref, idxs_ref, out_ref):
    out_ref[...] = vals_ref[...] ^ idxs_ref[...]


def gups_update(vals, idxs):
    """new_vals = vals ^ idxs over int32 lanes (GUPS payload batch)."""
    n = vals.shape[0]
    assert n % BLOCK == 0, f"n={n} must be a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _gups_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(vals, idxs)


def _triad_kernel(scalar_ref, b_ref, c_ref, out_ref):
    out_ref[...] = b_ref[...] + scalar_ref[0] * c_ref[...]


def stream_triad(b, c, scalar):
    """a = b + scalar * c (STREAM triad blocks)."""
    n = b.shape[0]
    assert n % BLOCK == 0
    grid = (n // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    scalar_arr = jnp.asarray(scalar, dtype=b.dtype).reshape((1,))
    return pl.pallas_call(
        _triad_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=True,
    )(scalar_arr, b, c)


def _hash_kernel(keys_ref, out_ref):
    h = (keys_ref[...].astype(jnp.uint32) * jnp.uint32(0x9E3779B9)).astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = (h * jnp.uint32(0x85EBCA6B)).astype(jnp.uint32)
    out_ref[...] = (h ^ (h >> jnp.uint32(13))).astype(jnp.int32)


def hash_mult(keys):
    """Batched multiplicative hash (KV-workload bucket selection)."""
    n = keys.shape[0]
    assert n % BLOCK == 0
    grid = (n // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _hash_kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(keys)


# SpMV: one grid step per row tile; the x vector is small enough to sit in
# VMEM whole (matching the workload, where x is the node-local vector and
# only the matrix streams from far memory). The inner contraction maps onto
# the MXU when nnz is padded to the 128 lane multiple.
ROW_TILE = 8


def _spmv_kernel(vals_ref, cols_ref, x_ref, out_ref):
    x = x_ref[...]
    vals = vals_ref[...]
    cols = cols_ref[...]
    gathered = x[cols]  # (ROW_TILE, nnz) gather from VMEM
    out_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=())
def spmv_ell(vals, cols, x):
    """y[r] = sum_j vals[r,j] * x[cols[r,j]] for an ELL row block."""
    rows, nnz = vals.shape
    assert rows % ROW_TILE == 0
    grid = (rows // ROW_TILE,)
    mat_spec = pl.BlockSpec((ROW_TILE, nnz), lambda i: (i, 0))
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            mat_spec,
            mat_spec,
            pl.BlockSpec(x.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), vals.dtype),
        interpret=True,
    )(vals, cols, x)
