"""Layer-2 JAX model: the payload engine the Rust coordinator loads.

Composes the Layer-1 Pallas kernels into the jitted entry points that are
AOT-lowered to HLO text (see `aot.py`). Build-time only — never imported on
the simulation path.
"""

import jax
import jax.numpy as jnp

from .kernels import gups as k

# Fixed AOT shapes: one executable per entry point, mirrored by
# rust/src/runtime/payload.rs.
GUPS_BATCH = 4096
TRIAD_N = 8192
HASH_BATCH = 4096
SPMV_ROWS = 256
SPMV_NNZ = 32
SPMV_XLEN = 2048


def gups_step(vals, idxs):
    """Fused GUPS payload step: hash the indices into the table's index
    space *and* apply the xor update — the full far-memory-side transform
    for one batch of updates."""
    hashed = k.hash_mult(idxs)
    return k.gups_update(vals, hashed)


def entry_points():
    """(name, fn, example_args) for every AOT artifact."""
    i32 = jnp.int32
    f32 = jnp.float32
    return [
        (
            "gups_update",
            k.gups_update,
            (
                jax.ShapeDtypeStruct((GUPS_BATCH,), i32),
                jax.ShapeDtypeStruct((GUPS_BATCH,), i32),
            ),
        ),
        (
            "gups_step",
            gups_step,
            (
                jax.ShapeDtypeStruct((GUPS_BATCH,), i32),
                jax.ShapeDtypeStruct((GUPS_BATCH,), i32),
            ),
        ),
        (
            "stream_triad",
            lambda b, c: k.stream_triad(b, c, 3.0),
            (
                jax.ShapeDtypeStruct((TRIAD_N,), f32),
                jax.ShapeDtypeStruct((TRIAD_N,), f32),
            ),
        ),
        (
            "hash_mult",
            k.hash_mult,
            (jax.ShapeDtypeStruct((HASH_BATCH,), i32),),
        ),
        (
            "spmv_ell",
            k.spmv_ell,
            (
                jax.ShapeDtypeStruct((SPMV_ROWS, SPMV_NNZ), f32),
                jax.ShapeDtypeStruct((SPMV_ROWS, SPMV_NNZ), i32),
                jax.ShapeDtypeStruct((SPMV_XLEN,), f32),
            ),
        ),
    ]
