"""AOT lowering: jax -> HLO *text* artifacts for the Rust PJRT runtime.

HLO text (NOT `lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()`)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the `xla` crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Runs once from `make artifacts`; the Rust binary is self-contained after.
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn, example_args in model.entry_points():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest)} entries")
    return 0


if __name__ == "__main__":
    sys.exit(main())
