"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and values; every kernel must match `ref.py`
bit-for-bit on integers and to float tolerance on floats.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gups as k
from compile.kernels import ref

BLOCKS = st.integers(min_value=1, max_value=4)


def i32_array(rng, n):
    return jnp.asarray(rng.integers(-(2**31), 2**31 - 1, size=n, dtype=np.int64).astype(np.int32))


@settings(max_examples=20, deadline=None)
@given(blocks=BLOCKS, seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_gups_update_matches_ref(blocks, seed):
    rng = np.random.default_rng(seed)
    n = blocks * k.BLOCK
    vals, idxs = i32_array(rng, n), i32_array(rng, n)
    out = k.gups_update(vals, idxs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.gups_update_ref(vals, idxs)))


@settings(max_examples=20, deadline=None)
@given(blocks=BLOCKS, seed=st.integers(min_value=0, max_value=2**32 - 1),
       scalar=st.floats(min_value=-8.0, max_value=8.0, allow_nan=False))
def test_stream_triad_matches_ref(blocks, seed, scalar):
    rng = np.random.default_rng(seed)
    n = blocks * k.BLOCK
    b = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    c = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    out = k.stream_triad(b, c, scalar)
    # interpret-mode pallas may fuse multiply-add differently: float tol.
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.stream_triad_ref(b, c, np.float32(scalar))),
        rtol=1e-4, atol=1e-5,
    )


@settings(max_examples=20, deadline=None)
@given(blocks=BLOCKS, seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_hash_mult_matches_ref(blocks, seed):
    rng = np.random.default_rng(seed)
    n = blocks * k.BLOCK
    keys = i32_array(rng, n)
    out = k.hash_mult(keys)
    want = ref.hash_mult_ref(np.asarray(keys).astype(np.uint32)).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(row_tiles=st.integers(min_value=1, max_value=4),
       nnz=st.sampled_from([8, 27, 32]),
       seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_spmv_ell_matches_ref(row_tiles, nnz, seed):
    rng = np.random.default_rng(seed)
    rows = row_tiles * k.ROW_TILE
    xlen = 256
    vals = jnp.asarray(rng.standard_normal((rows, nnz), dtype=np.float32))
    cols = jnp.asarray(rng.integers(0, xlen, size=(rows, nnz), dtype=np.int64).astype(np.int32))
    x = jnp.asarray(rng.standard_normal(xlen, dtype=np.float32))
    out = k.spmv_ell(vals, cols, x)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.spmv_ell_ref(vals, cols, x)), rtol=1e-3, atol=1e-4
    )


def test_gups_rejects_unaligned_batch():
    with pytest.raises(AssertionError):
        k.gups_update(jnp.zeros(100, jnp.int32), jnp.zeros(100, jnp.int32))


def test_gups_step_composes_hash_and_xor():
    from compile import model
    rng = np.random.default_rng(7)
    n = model.GUPS_BATCH
    vals, idxs = i32_array(rng, n), i32_array(rng, n)
    out = model.gups_step(vals, idxs)
    hashed = ref.hash_mult_ref(np.asarray(idxs).astype(np.uint32)).astype(np.int32)
    want = np.asarray(vals) ^ hashed
    np.testing.assert_array_equal(np.asarray(out), want)


def test_entry_points_lower_to_hlo_text():
    """Every AOT entry must lower through the HLO-text path (the exact
    mechanism `make artifacts` uses)."""
    from compile import aot, model
    for name, fn, example_args in model.entry_points():
        lowered = jax.jit(fn).lower(*example_args)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, f"{name}: no HLO text produced"
        assert len(text) > 100, f"{name}: implausibly small HLO"
